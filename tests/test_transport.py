"""Process-isolated worker transport (DESIGN.md §15): wire + supervisor.

The contract under test:

* **no byte corruption survives the wire** — every malformed frame
  (truncated, torn, CRC-flipped, version-skewed, dtype-smuggling) raises
  ``WireError``, a subclass of ``TornResultError``, so a corrupt frame
  fails over exactly like a torn in-process reply and never reaches the
  merge (fuzz-pinned);
* **structured errors cross the process boundary as structure** — the
  serving exceptions round-trip with their cells/shard_ids/attempts
  context intact, and unknown types degrade to a tagged
  ``RemoteWorkerError`` instead of being misclassified;
* **the proc backend is bit-invisible** — ``workers="proc"`` serves bits
  identical to the in-process fleet (fp32 wire exact; bf16 wire idempotent
  with the bf16-wire merge);
* **real SIGKILL mid-batch is survivable at R=2** — one replica of every
  shard killed mid-stream yields bit-identical results and coverage 1.0,
  the corpses respawn from their snapshot images into PROBATION, and the
  respawned workers SERVE when traffic is forced onto them (the
  acceptance criterion);
* **deadlines bound real socket waits** — a slow worker's reply is
  abandoned at the socket deadline, its late reply is discarded by seq
  (never served), and the bounded in-flight queue refuses further calls
  with ``BackpressureError``;
* **liveness is supervised** — a wedged (SIGSTOPped) worker fails the
  heartbeat probe and is respawned; graceful drain exits every worker 0.
"""
import json
import os
import signal
import struct
import time
import zlib
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topk import next_pow2
from repro.serving import (BackpressureError, CallPolicy, FaultPolicy,
                           FaultyWorker, HealthState, HealthTracker,
                           RemoteWorkerError, RetrievalIndex, ShardRouter,
                           ShardUnavailableError, SnapshotError,
                           TornResultError, WireError, WorkerCrashedError,
                           WorkerSupervisor, WorkerTimeoutError,
                           aggregate_topk, load_fleet, validate_run)
from repro.serving import transport as T
from repro.serving.health import Attempt
from repro.serving.shards import MissingShardError
from repro.serving.snapshot import save_shards
from repro.serving.supervisor import SupervisorConfig
from repro.data.synthetic import clustered_vectors

N, D, K, NCELLS, NSHARDS = 1024, 16, 10, 8, 2
CFG = dict(ivf_cells=NCELLS, nprobe=4, overfetch=8)


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """One IVF index, its R=2 shard fleet root, and the inproc baseline."""
    vecs = clustered_vectors(N, D, seed=5)
    idx = RetrievalIndex.build(np.arange(N), vecs, **CFG)
    q = clustered_vectors(24, D, seed=6)
    root = str(tmp_path_factory.mktemp("rpc") / "fleet")
    save_shards(idx, root, NSHARDS, replicas=2)
    base = load_fleet(root, replicas=1).search(q, K)
    return SimpleNamespace(q=q, root=root, base=base)


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances))


# -- framing -----------------------------------------------------------------


def test_frame_roundtrip():
    arrays = {
        "q": np.arange(12, dtype=np.float32).reshape(3, 4),
        "ids": np.array([[1, -1], [7, 8]], dtype=np.int32),
        "mask": np.array([True, False]),
        "scalar": np.array(3, dtype=np.int64),
    }
    meta = {"seq": 42, "k": 10, "note": "héllo"}
    buf = T.pack_frame(T.F_QUERY, meta, arrays)
    ftype, m, a, consumed = T.unpack_frame(buf)
    assert ftype == T.F_QUERY and consumed == len(buf)
    assert m == {"seq": 42, "k": 10, "note": "héllo"}
    assert sorted(a) == sorted(arrays)
    for name in arrays:
        np.testing.assert_array_equal(a[name], arrays[name])
        assert a[name].dtype == arrays[name].dtype
    # Two frames back to back: consumed delimits the first exactly.
    combo = T.pack_frame(T.F_PING) + buf
    ftype2, _, _, c2 = T.unpack_frame(combo)
    assert ftype2 == T.F_PING
    ftype3, m3, _, _ = T.unpack_frame(combo[c2:])
    assert ftype3 == T.F_QUERY and m3 == m


def test_pack_refuses_bad_inputs():
    with pytest.raises(WireError, match="unknown frame type"):
        T.pack_frame(99)
    # Send-side dtype whitelist: float16 and object never hit the wire.
    with pytest.raises(WireError, match="refusing to send"):
        T.pack_frame(T.F_RESULT, {}, {"v": np.zeros(2, np.float16)})


def _craft(ftype: int, payload: bytes) -> bytes:
    """A frame with a VALID checksum for an arbitrary type/payload — lets
    the tests reach parse errors deeper than the CRC gate."""
    crc = zlib.crc32(payload, zlib.crc32(
        struct.pack("<4sHH", T.WIRE_MAGIC, T.WIRE_VERSION, ftype)))
    return T._HEADER.pack(T.WIRE_MAGIC, T.WIRE_VERSION, ftype,
                          len(payload), crc) + payload


def test_unpack_rejects_each_malformation():
    frame = T.pack_frame(T.F_RESULT, {"seq": 1},
                         {"v": np.arange(8, dtype=np.float32)})
    with pytest.raises(WireError, match="truncated frame header"):
        T.unpack_frame(frame[: T.HEADER_BYTES - 1])
    with pytest.raises(WireError, match="bad frame magic"):
        T.unpack_frame(b"XXXX" + frame[4:])
    ver = T._HEADER.pack(T.WIRE_MAGIC, T.WIRE_VERSION + 1, T.F_RESULT, 0, 0)
    with pytest.raises(WireError, match="wire version"):
        T.unpack_frame(ver)
    with pytest.raises(WireError, match="truncated frame payload"):
        T.unpack_frame(frame[:-3])
    crc_flip = bytearray(frame)
    crc_flip[-1] ^= 0xFF  # payload tail: CRC must catch it
    with pytest.raises(WireError, match="CRC mismatch"):
        T.unpack_frame(bytes(crc_flip))
    # Unknown frame type with a valid checksum.
    with pytest.raises(WireError, match="unknown frame type"):
        T.unpack_frame(_craft(77, frame[T.HEADER_BYTES:]))
    # A flipped TYPE byte must fail the CRC, not relabel the message.
    relabel = bytearray(frame)
    relabel[6] ^= 1  # F_RESULT -> F_QUERY, payload untouched
    with pytest.raises(WireError, match="CRC mismatch"):
        T.unpack_frame(bytes(relabel))

    def crafted(payload: bytes) -> bytes:
        return _craft(T.F_RESULT, payload)

    with pytest.raises(WireError, match="not valid JSON"):
        T.unpack_frame(crafted(struct.pack("<I", 8) + b"not json"))
    with pytest.raises(WireError, match="arrays manifest"):
        T.unpack_frame(crafted(struct.pack("<I", 2) + b"{}"))
    # A spec naming a dtype off the whitelist cannot smuggle np.dtype(evil).
    meta = json.dumps({"arrays": [{"name": "v", "dtype": "object",
                                   "shape": [1]}]}).encode()
    with pytest.raises(WireError, match="not admitted"):
        T.unpack_frame(crafted(struct.pack("<I", len(meta)) + meta))
    meta = json.dumps({"arrays": [{"name": "v", "dtype": "float32",
                                   "shape": [-1]}]}).encode()
    with pytest.raises(WireError, match="negative array dim"):
        T.unpack_frame(crafted(struct.pack("<I", len(meta)) + meta))
    # Blob bytes disagreeing with the declared shape, both directions.
    meta = json.dumps({"arrays": [{"name": "v", "dtype": "float32",
                                   "shape": [4]}]}).encode()
    with pytest.raises(WireError, match="truncated"):
        T.unpack_frame(crafted(struct.pack("<I", len(meta)) + meta + b"\0" * 8))
    with pytest.raises(WireError, match="trailing bytes"):
        T.unpack_frame(crafted(struct.pack("<I", len(meta)) + meta
                               + b"\0" * 24))


def test_fuzz_byte_corruption_never_parses_wrong():
    """Satellite: fuzz contract — ANY single-byte flip or truncation either
    raises WireError or yields the original message, never a third thing."""
    frame = T.pack_frame(T.F_RESULT, {"seq": 7, "k": 10},
                         {"vals": np.linspace(0, 1, 24, dtype=np.float32)
                          .reshape(3, 8),
                          "ids": np.arange(24, dtype=np.int32).reshape(3, 8)})
    want = T.unpack_frame(frame)
    rng = np.random.default_rng(1234)
    for _ in range(300):
        buf = bytearray(frame)
        pos = int(rng.integers(len(buf)))
        flip = int(rng.integers(1, 256))
        buf[pos] ^= flip  # guaranteed to differ at pos
        try:
            got = T.unpack_frame(bytes(buf))
        except WireError:
            continue
        # The only acceptable parse of a corrupt buffer is the original.
        assert got[0] == want[0] and got[1] == want[1], (pos, flip)
        for name in want[2]:
            np.testing.assert_array_equal(got[2][name], want[2][name])
    for _ in range(100):  # torn frames: every truncation point fails loudly
        n = int(rng.integers(len(frame)))
        with pytest.raises(WireError):
            T.unpack_frame(frame[:n])


def test_wire_error_fails_over_like_torn_result():
    assert issubclass(WireError, TornResultError)
    # The failover wrapper counts it as a worker failure like any raise.
    from repro.serving import run_with_failover

    def corrupt():
        raise WireError("frame payload CRC mismatch")

    tracker = HealthTracker()
    out, attempts = run_with_failover(
        [("bad", corrupt), ("good", lambda: "served")],
        policy=CallPolicy(), tracker=tracker)
    assert out == "served"
    assert attempts[0].error and "CRC" in attempts[0].error
    assert tracker.state("bad") is HealthState.DEGRADED


def test_frame_overhead_model_tracks_framing():
    base = T.frame_overhead_bytes({"seq": 1}, n_arrays=0)
    assert base > T.HEADER_BYTES
    assert T.frame_overhead_bytes({"seq": 1}, n_arrays=2) > \
        T.frame_overhead_bytes({"seq": 1}, n_arrays=1) > base


# -- result wire -------------------------------------------------------------


def test_result_wire_fp32_is_bit_exact():
    rng = np.random.default_rng(3)
    vals = np.sort(rng.random((4, 16)).astype(np.float32), axis=-1)
    ids = rng.integers(0, 1 << 20, size=(4, 16)).astype(np.int64)
    _, _, arrays, _ = T.unpack_frame(
        T.pack_frame(T.F_RESULT, {"seq": 1}, T.encode_result(vals, ids)))
    got_v, got_i = T.decode_result(arrays)
    np.testing.assert_array_equal(got_v, vals)
    np.testing.assert_array_equal(got_i, ids.astype(np.int32))
    assert got_v.dtype == np.float32 and got_i.dtype == np.int32


def test_result_wire_bf16_idempotent_with_bf16_merge():
    """Shipping runs in bf16 changes ZERO bits of the bf16-wire merge:
    encode's cast is the same rounding aggregate_topk applies before its
    first merge round."""
    S, m, Kp = 3, 4, next_pow2(K)
    rng = np.random.default_rng(11)
    vals = np.sort(rng.random((S, m, Kp)).astype(np.float32), axis=-1)
    ids = rng.integers(0, N, size=(S, m, Kp)).astype(np.int32)
    want = aggregate_topk(jnp.asarray(vals), jnp.asarray(ids), K,
                          wire_dtype="bfloat16")
    shipped = []
    for s in range(S):
        _, _, arrays, _ = T.unpack_frame(T.pack_frame(
            T.F_RESULT, {},
            T.encode_result(vals[s], ids[s], wire_dtype="bfloat16")))
        v, i = T.decode_result(arrays)
        assert v.dtype == np.float32  # decode always hands back fp32
        shipped.append(v)
    got = aggregate_topk(jnp.asarray(np.stack(shipped)), jnp.asarray(ids), K,
                         wire_dtype="bfloat16")
    np.testing.assert_array_equal(np.asarray(want.distances),
                                  np.asarray(got.distances))
    np.testing.assert_array_equal(np.asarray(want.indices),
                                  np.asarray(got.indices))


def test_decode_result_validates():
    with pytest.raises(WireError, match="missing runs"):
        T.decode_result({"vals": np.zeros((1, 2), np.float32)})
    with pytest.raises(WireError, match="not integral"):
        T.decode_result({"vals": np.zeros((1, 2), np.float32),
                         "ids": np.zeros((1, 2), np.float32)})


# -- error wire (satellite: structured errors round-trip) --------------------


def test_error_roundtrip_preserves_context():
    attempts = (Attempt("s1r0", 0.012, "WorkerCrashedError: down"),
                Attempt("s1r1", 0.034, None))
    e = ShardUnavailableError("every replica of shard 1 failed",
                              cells=(3, 4), shard_ids=(1,), attempts=attempts)
    # Through a REAL frame, not just the codec: meta JSON-ifies the context.
    _, meta, _, _ = T.unpack_frame(
        T.pack_frame(T.F_ERROR, {"seq": 9, "error": T.encode_error(e)}))
    r = T.decode_error(meta["error"])
    assert type(r) is ShardUnavailableError
    assert isinstance(r, MissingShardError)  # callers still catch one type
    assert str(r) == str(e)
    assert r.cells == (3, 4) and r.shard_ids == (1,)
    assert r.attempts == attempts  # real Attempt records, None preserved
    assert all(isinstance(a, Attempt) for a in r.attempts)

    m = MissingShardError("cells owned by no loaded shard", cells=(7,))
    rm = T.roundtrip_error(m)
    assert type(rm) is MissingShardError and rm.cells == (7,)


def test_error_roundtrip_plain_and_unknown_types():
    for cls in (TornResultError, WireError, SnapshotError,
                WorkerCrashedError, WorkerTimeoutError, BackpressureError):
        r = T.roundtrip_error(cls("boom"))
        assert type(r) is cls and str(r) == "boom"
    # Unknown types degrade to a TAGGED RemoteWorkerError, never a guess.
    r = T.roundtrip_error(ValueError("k must be positive"))
    assert type(r) is RemoteWorkerError
    assert r.remote_type == "ValueError"
    assert "ValueError" in str(r) and "k must be positive" in str(r)


def test_attempts_from_wire():
    raw = [["w0", 0.5, "err"], ["w1", 1, None]]
    assert T.attempts_from_wire(raw) == (Attempt("w0", 0.5, "err"),
                                         Attempt("w1", 1.0, None))


# -- the analytic RPC traffic model ------------------------------------------


def test_rpc_bytes_model():
    from repro.accounting import rpc_bytes_per_batch

    m = rpc_bytes_per_batch(64, 128, k=K, shards_dispatched=3.0)
    Kp = next_pow2(K)
    assert m["request"] > 64 * 128 * 4  # query block + real frame overhead
    assert m["reply"] > 64 * Kp * 8
    # The architecture's point: requests are O(m·d), replies O(m·K) — the
    # aggregator stays thin because workers ship runs, not candidates.
    assert m["reply"] < m["request"]
    assert m["fleet_total"] == pytest.approx(3.0 * m["per_shard"])
    assert m["per_query"] == pytest.approx(m["fleet_total"] / 64)
    bf16 = rpc_bytes_per_batch(64, 128, k=K, wire_bytes_per_value=2)
    assert bf16["reply"] < m["reply"]
    assert bf16["request"] == m["request"]  # queries stay fp32


# -- the proc backend: real worker processes ---------------------------------


def test_proc_fleet_bit_identical_and_graceful_drain(fleet):
    """workers="proc" serves the same bits as inproc; deadlines bind the
    real socket timeout; a malformed QUERY comes back as a typed WireError
    without killing the worker; drain exits every worker 0."""
    router = load_fleet(fleet.root, workers="proc", replicas=2,
                        call_policy=CallPolicy(deadline_s=60.0))
    sup = router.supervisor
    try:
        # The router's deadline bounds REAL socket waits on every worker.
        assert sup.timeout_s == 60.0
        assert all(w._sock.gettimeout() == 60.0 for w in sup.workers)
        assert {w.key for w in sup.workers} == \
            {f"s{s}r{r}" for s in range(NSHARDS) for r in range(2)}
        assert all(w.alive and w.pid is not None for w in sup.workers)
        # HELLO-announced metadata matches the shard images: live rows are
        # counted once per range, replicas are restores of the same image.
        assert sum(w.n_live for w in sup.workers) == 2 * N
        assert all(w.dim == D for w in sup.workers)

        got = router.search(fleet.q, K)
        _assert_bit_identical(fleet.base, got)
        assert np.all(np.asarray(got.coverage) == 1.0)

        # A QUERY missing its q array: the worker answers with a typed
        # ERROR frame (WireError, with our seq) and keeps serving.
        w = sup.workers[0]
        w._seq += 1
        T.send_frame(w._sock, T.F_QUERY, {"seq": w._seq, "k": K})
        ftype, meta, _ = T.recv_frame(w._sock)
        assert ftype == T.F_ERROR and meta["seq"] == w._seq
        err = T.decode_error(meta["error"])
        assert type(err) is WireError and "q array" in str(err)
        _assert_bit_identical(fleet.base, router.search(fleet.q, K))

        assert sup.summary()["respawns"] == 0
        procs = [w._proc for w in sup.workers]
    finally:
        sup.shutdown(drain=True)
    # Graceful drain: DRAIN → BYE → exit 0, no worker terminated/killed.
    assert [p.wait(timeout=10) for p in procs] == [0] * len(procs)
    assert not any(w.alive for w in sup.workers)


def test_proc_bf16_wire_matches_inproc_bf16(fleet):
    """The bf16 value wire end to end: a proc fleet shipping bf16 runs is
    bit-identical to the inproc fleet merging with the bf16 wire."""
    inproc = load_fleet(fleet.root, replicas=1, wire_dtype="bfloat16")
    want = inproc.search(fleet.q, K)
    router = load_fleet(fleet.root, workers="proc", replicas=1,
                        wire_dtype="bfloat16")
    try:
        _assert_bit_identical(want, router.search(fleet.q, K))
    finally:
        router.supervisor.shutdown(drain=False)


def test_sigkill_one_replica_of_every_shard_mid_batch(fleet):
    """The acceptance criterion, on real processes: at R=2, SIGKILL one
    replica of every shard MID-BATCH → bit-identical results, coverage
    1.0; the corpses respawn from their snapshot images into PROBATION;
    then the surviving replicas are killed mid-batch too, forcing traffic
    onto the respawned workers — which serve, and graduate to HEALTHY."""
    router0 = load_fleet(fleet.root, workers="proc", replicas=2,
                         degraded="partial")
    sup = router0.supervisor
    try:
        kill0 = {f"s{s}r0" for s in range(NSHARDS)}
        kill1 = {f"s{s}r1" for s in range(NSHARDS)}
        pids = {w.key: w.pid for w in sup.workers}
        # The kill fault schedule (satellite: chaos suites get a "kill"
        # kind): replica 0 dies at its first consult — batch 1, because
        # the round-robin rotation starts every group at replica 0; the
        # survivor dies at its call 2 — batch 3, after serving batches
        # 1 (failover) and 2.
        wrapped = [FaultyWorker(w, FaultPolicy.kill_at(0)) if w.key in kill0
                   else FaultyWorker(w, FaultPolicy.kill_at(2))
                   for w in router0.workers]
        router = ShardRouter(wrapped, degraded="partial",
                             call_policy=CallPolicy(), supervisor=sup)

        # Batch 1: every shard's replica 0 is SIGKILLed mid-batch; the
        # broken pipe is discovered in-flight and failover eats it whole.
        got = router.search(fleet.q, K)
        _assert_bit_identical(fleet.base, got)
        assert np.all(np.asarray(got.coverage) == 1.0)
        assert all(st == "ok" for _, st in got.shard_status)
        assert all(router.health.state(k) is HealthState.DEGRADED
                   for k in kill0)
        assert all(not w.alive for w in sup.workers if w.key in kill0)

        # Batch 2: the supervisor's pre-dispatch poll respawns the corpses
        # from their shard images; they re-enter routing as PROBATION
        # while the healthy survivors carry the batch.
        _assert_bit_identical(fleet.base, router.search(fleet.q, K))
        assert sup.respawns == NSHARDS
        assert all(router.health.state(k) is HealthState.PROBATION
                   for k in kill0)
        for w in sup.workers:
            if w.key in kill0:
                assert w.alive and w.respawns == 1 and w.pid != pids[w.key]

        # Batch 3: now the SURVIVORS are killed mid-batch — traffic is
        # forced onto the respawned workers, which must actually serve
        # (respawn-to-serving, not just respawn-to-alive).
        got = router.search(fleet.q, K)
        _assert_bit_identical(fleet.base, got)
        assert np.all(np.asarray(got.coverage) == 1.0)
        assert all(router.health.state(k) is HealthState.HEALTHY
                   for k in kill0)  # probation trial served and passed
        assert all(router.health.state(k) is HealthState.DEGRADED
                   for k in kill1)

        # Batch 4: the second wave respawns too; the whole fleet is live
        # again and every worker has a fresh pid.
        _assert_bit_identical(fleet.base, router.search(fleet.q, K))
        assert sup.respawns == 2 * NSHARDS
        assert all(w.alive and w.pid != pids[w.key] for w in sup.workers)
        assert all(f.faults_injected == 1 for f in wrapped)
    finally:
        sup.shutdown(drain=False)


def test_deadline_abandons_slow_reply_then_discards_it_stale(fleet):
    """A worker answering past the socket deadline: the call times out
    (worker NOT marked dead — slow is not crashed), the in-flight budget
    refuses further calls (backpressure), and the late reply is retired
    by its stale seq — discarded, never served."""
    sup = WorkerSupervisor(SupervisorConfig(heartbeat_s=60.0))
    try:
        sup.spawn_fleet(fleet.root, replicas=1)
        w = next(x for x in sup.workers if x.key == "s0r0")
        warm = w.topk(fleet.q, K)  # compiles the worker-side scan
        validate_run(warm, len(fleet.q), next_pow2(K))

        w.test_delay_s = 0.6
        w._sock.settimeout(0.15)  # what CallPolicy.deadline_s binds
        with pytest.raises(WorkerTimeoutError):
            w.topk(fleet.q, K)
        assert w.alive and w._pending == 1  # abandoned, not crashed

        # Bounded in-flight queue: at the budget, calls are refused
        # loudly instead of piling onto a struggling worker.
        w.queue_depth = 1
        with pytest.raises(BackpressureError):
            w.topk(fleet.q, K)
        w.queue_depth = sup.cfg.queue_depth

        # The worker eventually answers the abandoned request; the next
        # call reads that stale reply first, retires it by seq, and
        # serves only its own — bit-identical to the warm result.
        w.test_delay_s = 0.0
        w._sock.settimeout(30.0)
        got = w.topk(fleet.q, K)
        np.testing.assert_array_equal(np.asarray(got.distances),
                                      np.asarray(warm.distances))
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(warm.indices))
        assert w._pending == 0  # the stale reply was retired, not leaked
        w.ping()
    finally:
        sup.shutdown(drain=False)


def test_heartbeat_detects_wedged_worker_and_respawns(fleet):
    """SIGSTOP leaves a process alive-but-wedged — exit-code polling can't
    see it; the idle heartbeat PING times out, the worker is declared
    dead, respawned from its image, and re-admitted as PROBATION."""
    cfg = SupervisorConfig(heartbeat_s=0.05, heartbeat_timeout_s=0.3)
    sup = WorkerSupervisor(cfg)
    try:
        sup.spawn_fleet(fleet.root, replicas=1)
        w = next(x for x in sup.workers if x.key == "s0r0")
        old_pid = w.pid
        os.kill(w.pid, signal.SIGSTOP)
        assert w.alive  # the lie the heartbeat exists to catch
        with pytest.raises(WorkerTimeoutError):
            w.ping(timeout_s=0.2)
        time.sleep(0.06)  # past heartbeat_s: poll must probe idle workers
        tracker = HealthTracker()
        respawned = sup.poll(tracker)
        assert "s0r0" in respawned
        assert tracker.state("s0r0") is HealthState.PROBATION
        assert w.alive and w.pid != old_pid and w.respawns == 1
        validate_run(w.topk(fleet.q, K), len(fleet.q), next_pow2(K))
    finally:
        sup.shutdown(drain=False)


def test_restore_failure_ships_as_typed_error(fleet, tmp_path):
    """A worker that cannot restore its image reports a structured
    SnapshotError over the wire — the parent raises the same typed error
    an in-process restore would have, and no process leaks."""
    import shutil

    root = str(tmp_path / "corrupt")
    shutil.copytree(fleet.root, root)
    mpath = os.path.join(root, "shard-000", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["files"]["shard.npz"]["crc32"] ^= 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    # Parent-side manifest reads skip verification (the child re-verifies
    # hard), so the failure surfaces through the child's ERROR frame.
    with pytest.raises(SnapshotError, match="corrupted/truncated"):
        load_fleet(root, workers="proc", replicas=1)


def test_load_fleet_rejects_unknown_backend(fleet):
    with pytest.raises(ValueError, match="workers"):
        load_fleet(fleet.root, workers="threads")
