"""repro.serving.shards: cell-range sharding, routing, thin-aggregator merge.

The contract under test (DESIGN.md §13):

* **zero-retraining distribution** — per-shard images restored in FRESH
  subprocesses (``core.kmeans.lloyd`` tripwired) answer local top-k, and the
  parent-side butterfly aggregate of those runs is BIT-identical (values and
  ids) to the single-host index's ``search`` when the probe set and overfetch
  span the corpus, and reaches recall@10 >= 0.95 at serving defaults;
* **routing is a partition** — every probed cell maps to exactly one owning
  shard and the dispatched set covers the probe set, for arbitrary cell-range
  partitions (property test); a probe into an unowned cell raises
  ``MissingShardError``, never a silently partial result;
* **the aggregator is exact and dispatch-stable** — ``aggregate_topk`` equals
  a flat sort of the concatenated per-shard candidates, including
  duplicate-distance ties and +inf/-1 tombstone entries, for random shard
  counts and k (property test), and skipping undispatched shards does not
  change a single result bit;
* **assembly is the fault barrier** — overlapping cell ranges, mixed parent
  snapshot signatures, or an incomplete strict fleet raise ``SnapshotError``
  before anything serves.
"""
import json
import os
import shutil
from types import SimpleNamespace

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as T
from repro.core.knn import knn_query
from repro.data.synthetic import clustered_vectors
from repro.serving import (MissingShardError, RetrievalIndex, ShardRouter,
                           ShardSpec, ShardWorker, SnapshotError,
                           aggregate_topk, load_router, plan_shards)
from repro.serving.snapshot import restore_shard, save_shards, shard_dirs

N, D, K, NCELLS, NSHARDS = 2048, 32, 10, 16, 4
# nprobe = ncells and an overfetch window spanning the corpus: both the
# routed and the single-host path degenerate to the exact rescored top-k
# over every live row — the bit-identity regime (DESIGN.md §13).
EXHAUSTIVE = dict(ivf_cells=NCELLS, nprobe=NCELLS, pq_m=8, overfetch=128)
DEFAULTS = dict(nprobe=8, overfetch=4)  # serving defaults for the recall bar


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """One exhaustive-config IVFADC index + its 4-way shard image fleet."""
    vecs = clustered_vectors(N, D, seed=7)
    idx = RetrievalIndex.build(np.arange(N), vecs, **EXHAUSTIVE)
    q = clustered_vectors(24, D, seed=9)
    root = str(tmp_path_factory.mktemp("shards") / "fleet")
    paths = save_shards(idx, root, NSHARDS)
    return SimpleNamespace(idx=idx, vecs=vecs, q=q, root=root, paths=paths)


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances))


def _recall(got_ids, want_ids):
    return np.mean([len(set(g) & set(w)) / len(w)
                    for g, w in zip(np.asarray(got_ids), np.asarray(want_ids))])


# -- the headline: multi-process restore + route + aggregate -----------------


def test_multiprocess_shard_restore_routes_bit_identical(fleet, tmp_path):
    """Each shard restores in a FRESH process (zero retraining — Lloyd is
    tripwired), answers local top-k at both the exhaustive and the
    serving-default knobs; the parent-side aggregate is bit-identical to the
    single-host search and clears the recall bar."""
    from conftest import run_with_devices

    qfile = str(tmp_path / "q.npz")
    np.savez(qfile, q=fleet.q)
    outs = []
    for sd in fleet.paths:
        out = str(tmp_path / (os.path.basename(sd) + "-runs.npz"))
        outs.append(out)
        run_with_devices(f"""
            import numpy as np
            import repro.core.kmeans as KM
            def _tripwire(*a, **kw):
                raise AssertionError("training entered on shard restore")
            KM.lloyd = _tripwire
            from repro.serving.snapshot import restore_shard
            w = restore_shard({sd!r})
            with np.load({qfile!r}) as z:
                q = z["q"]
            ex = w.topk(q, {K})  # config knobs: nprobe=ncells, spanning scan
            de = w.topk(q, {K}, nprobe={DEFAULTS["nprobe"]},
                        overfetch={DEFAULTS["overfetch"]})
            np.savez({out!r},
                     ev=np.asarray(ex.distances), ei=np.asarray(ex.indices),
                     dv=np.asarray(de.distances), di=np.asarray(de.indices))
            print("shard", w.spec.shard_id, "restored,", w.n_live, "live")
        """, n_devices=1)

    runs = [dict(np.load(o)) for o in outs]
    # Exhaustive knobs: the aggregate must be bit-identical to single-host.
    got = aggregate_topk(jnp.stack([r["ev"] for r in runs]),
                         jnp.stack([r["ei"] for r in runs]), K)
    want = fleet.idx.search(fleet.q, K)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.distances),
                                  np.asarray(want.distances))
    # Serving-default knobs: approximate, but above the serving recall bar.
    de = aggregate_topk(jnp.stack([r["dv"] for r in runs]),
                        jnp.stack([r["di"] for r in runs]), K)
    exact = knn_query(jnp.asarray(fleet.q), jnp.asarray(fleet.vecs), K)
    assert _recall(de.indices, exact.indices) >= 0.95


def test_router_matches_single_host_bit_identical(fleet):
    router = load_router(shard_dirs(fleet.root))
    assert router.n_live == len(fleet.idx)
    got = router.search(fleet.q, K)
    _assert_bit_identical(fleet.idx.search(fleet.q, K), got)


def test_router_through_query_engine(fleet):
    """The router duck-types the index surface the engine batches onto."""
    from repro.serving import EngineConfig, QueryEngine

    router = load_router(shard_dirs(fleet.root))
    eng = QueryEngine(router, EngineConfig(k=K, min_batch=8, max_batch=64))
    got = eng.search(fleet.q, K)
    _assert_bit_identical(fleet.idx.search(fleet.q, K), got)
    assert eng.meter.summary()["compile_batches"] >= 1


def test_dispatch_skip_is_bit_stable_and_recall_holds(fleet, tmp_path):
    """At serving defaults (partial probe sets) the router skips shards no
    query probes; the skipped shards' +inf runs must not change one bit vs
    aggregating every worker's actual run.  Non-pow2 fleet (S=3) on purpose:
    the aggregator pads to 4."""
    idx = RetrievalIndex.build(np.arange(N), fleet.vecs,
                               ivf_cells=NCELLS, pq_m=8, **DEFAULTS)
    root = str(tmp_path / "fleet3")
    save_shards(idx, root, 3)
    router = load_router(shard_dirs(root))
    got = router.search(fleet.q, K)
    runs = [w.topk(fleet.q, K) for w in router.workers]
    full = aggregate_topk(jnp.stack([r.distances for r in runs]),
                          jnp.stack([r.indices for r in runs]), K)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(full.indices))
    np.testing.assert_array_equal(np.asarray(got.distances),
                                  np.asarray(full.distances))
    exact = knn_query(jnp.asarray(fleet.q), jnp.asarray(fleet.vecs), K)
    assert _recall(got.ids, exact.indices) >= 0.95


# -- property tests ----------------------------------------------------------


def _tiny_worker(spec, ncells, fingerprint="f0"):
    cap, d = 2, 4
    cfg = dict(dim=d, distance="sqeuclidean", scan_dtype="float32",
               overfetch=4, ivf_cells=ncells, nprobe=4, pq_m=0, pq_nbits=8)
    n_loc = spec.ncells_local * cap
    return ShardWorker(spec,
                       centroids=np.zeros((ncells, d), np.float32),
                       packed=np.zeros((n_loc, d), np.float32),
                       ids_of_slot=np.arange(n_loc, dtype=np.int32),
                       live=np.ones(n_loc, bool), config=cfg,
                       parent={"fingerprint": fingerprint})


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(n_shards=st.integers(1, NCELLS),
                  seed=st.integers(0, 100_000), use_plan=st.booleans())
def test_routing_partition_unique_owner_and_coverage(n_shards, seed, use_plan):
    """Every cell has exactly one owner; the dispatched shards cover the
    probe set — for planned AND arbitrary random cell-range partitions."""
    rng = np.random.default_rng(seed)
    if use_plan:
        specs = plan_shards(NCELLS, n_shards)
    else:
        cuts = sorted(rng.choice(np.arange(1, NCELLS), size=n_shards - 1,
                                 replace=False).tolist())
        bounds = [0] + cuts + [NCELLS]
        specs = [ShardSpec(i, n_shards, bounds[i], bounds[i + 1])
                 for i in range(n_shards)]
    # Exactly one owning shard per cell, straight off the spec ranges.
    for c in range(NCELLS):
        assert sum(s.cell_lo <= c < s.cell_hi for s in specs) == 1
    router = ShardRouter([_tiny_worker(s, NCELLS) for s in specs])
    probe = rng.integers(0, NCELLS, size=(3, rng.integers(1, 8)))
    owners = router.owners_of(probe)
    assert owners.shape == probe.shape
    for c, o in zip(probe.ravel(), owners.ravel()):
        w = router.workers[o].spec
        assert w.cell_lo <= c < w.cell_hi
    covered = set()
    for o in np.unique(owners):
        w = router.workers[o].spec
        covered.update(range(w.cell_lo, w.cell_hi))
    assert set(probe.ravel().tolist()) <= covered


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(n_shards=st.integers(1, 6), m=st.integers(1, 3),
                  k=st.sampled_from([1, 3, 4, 7, 10]),
                  seed=st.integers(0, 100_000), wire=st.booleans())
def test_aggregate_matches_flat_sort(n_shards, m, k, seed, wire):
    """Butterfly merge == flat sort of the concatenated per-shard runs, with
    heavy duplicate-distance ties, +inf/-1 tombstone entries, random shard
    counts (incl. non-pow2) and k; bf16 wire storage included (the drawn
    values are bf16-exact, so the flat-sort oracle still applies bitwise)."""
    K = T.next_pow2(k)
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 5, size=(n_shards, m, K)).astype(np.float32)
    ids = (np.arange(n_shards)[:, None, None] * 1000
           + np.arange(m)[None, :, None] * 100
           + np.arange(K)[None, None, :]).astype(np.int32)
    dead = rng.random((n_shards, m, K)) < 0.3
    vals[dead] = np.inf
    ids[dead] = -1
    order = np.argsort(vals, axis=-1, kind="stable")
    vals = np.take_along_axis(vals, order, axis=-1)
    ids = np.take_along_axis(ids, order, axis=-1)
    got = aggregate_topk(jnp.asarray(vals), jnp.asarray(ids), k,
                         wire_dtype="bfloat16" if wire else None)
    gv, gi = np.asarray(got.distances), np.asarray(got.indices)
    assert gv.shape == gi.shape == (m, k)
    for j in range(m):
        flat = np.sort(vals[:, j, :].ravel(), kind="stable")
        np.testing.assert_array_equal(gv[j], flat[:k])
        # Each returned (value, id) pair is an actual input entry, no entry
        # returned more often than it occurs (ties resolve to SOME real id).
        from collections import Counter

        pool = Counter(zip(vals[:, j, :].ravel().tolist(),
                           ids[:, j, :].ravel().tolist()))
        for v, i in zip(gv[j].tolist(), gi[j].tolist()):
            assert pool[(v, i)] > 0, (v, i)
            pool[(v, i)] -= 1


# -- fault paths -------------------------------------------------------------


def _tamper_shard_manifest(sd, fn):
    path = os.path.join(sd, "manifest.json")
    with open(path) as f:
        m = json.load(f)
    fn(m)
    with open(path, "w") as f:
        json.dump(m, f)


def test_overlapping_cell_ranges_raise(fleet, tmp_path):
    root = str(tmp_path / "fleet")
    shutil.copytree(fleet.root, root)
    dirs = shard_dirs(root)
    # Shift shard 1's range onto shard 0's (same width: per-shard geometry
    # still self-consistent, so only the fleet-level check can catch it).
    _tamper_shard_manifest(
        dirs[1], lambda m: m["shard"].update(cell_lo=2, cell_hi=6))
    with pytest.raises(SnapshotError, match="overlap"):
        load_router(dirs, strict=False)


def test_mixed_parent_snapshots_raise(fleet, tmp_path):
    other = RetrievalIndex.build(np.arange(N),
                                 clustered_vectors(N, D, seed=23),
                                 **EXHAUSTIVE)
    root = str(tmp_path / "other")
    save_shards(other, root, NSHARDS)
    mixed = [fleet.paths[0]] + shard_dirs(root)[1:]
    with pytest.raises(SnapshotError, match="parent snapshot signature"):
        load_router(mixed)


def test_incomplete_fleet_strict_raises_lazy_fails_per_query(fleet):
    with pytest.raises(SnapshotError, match="covers"):
        load_router(fleet.paths[:-1])
    router = load_router(fleet.paths[:-1], strict=False)
    # Exhaustive config: every query probes every cell, so any query hits
    # the missing shard's range — loud, never a silently partial top-k.
    with pytest.raises(MissingShardError, match="owned by no loaded shard"):
        router.search(fleet.q, K)


def test_save_shards_guards(fleet, tmp_path):
    flat = RetrievalIndex.build(np.arange(256),
                                clustered_vectors(256, 16, seed=3))
    with pytest.raises(SnapshotError, match="IVF"):
        save_shards(flat, str(tmp_path / "flat"), 2)
    churned = RetrievalIndex.build(np.arange(N), fleet.vecs, **EXHAUSTIVE)
    churned.upsert([N + 1], np.zeros((1, D), np.float32))
    with pytest.raises(SnapshotError, match="compact"):
        save_shards(churned, str(tmp_path / "delta"), 2)
    with pytest.raises(ValueError, match="n_shards"):
        plan_shards(NCELLS, 0)
    with pytest.raises(ValueError, match="n_shards"):
        plan_shards(NCELLS, NCELLS + 1)


def test_restore_shard_roundtrips_geometry(fleet):
    w = restore_shard(fleet.paths[1])
    assert w.spec == ShardSpec(1, NSHARDS, 4, 8)
    assert w.dim == D and w.pq_codes is not None
    assert w.packed.shape[0] == w.spec.ncells_local * w.cell_cap


# -- service layer -----------------------------------------------------------


def test_service_shards_roundtrip_and_config_mismatch(tmp_path):
    import jax

    from repro.configs import registry as REG
    from repro.models.nn import split_params
    from repro.serving import ServiceConfig, TwoTowerRetrievalService

    arch = REG.get("two-tower-retrieval")
    cfg = arch.smoke_config()
    values, _ = split_params(arch.init_params(jax.random.PRNGKey(0), cfg))
    root = str(tmp_path / "shards")
    svc = TwoTowerRetrievalService(
        values, cfg, ServiceConfig(k=5, ivf_cells=8, nprobe=8, shards=2,
                                   snapshot_dir=root))
    rng = np.random.default_rng(1)
    n = 512
    fields = rng.integers(0, min(cfg.i_sizes()),
                          size=(n, cfg.n_item_fields)).astype(np.int32)
    svc.build_corpus(np.arange(n), fields)
    ukeys = np.arange(7)
    ufields = rng.integers(0, min(cfg.u_sizes()),
                           size=(7, cfg.n_user_fields)).astype(np.int32)
    paths = svc.save_shards()
    assert len(paths) == 2
    svc.restore_shards()
    assert isinstance(svc.engine.index, ShardRouter)
    ids, scores = svc.recommend(ukeys, ufields)
    assert ids.shape == (7, 5) and np.all(ids >= 0)

    # A service with different retrieval knobs must refuse the images.
    svc2 = TwoTowerRetrievalService(
        values, cfg, ServiceConfig(k=5, ivf_cells=8, nprobe=4,
                                   snapshot_dir=root))
    svc2.build_corpus(np.arange(n), fields)
    with pytest.raises(SnapshotError, match="config does not match"):
        svc2.restore_shards()
