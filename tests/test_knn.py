"""End-to-end kNN solver vs brute-force oracle (the paper's problem)."""
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as T
from repro.core.knn import knn_allpairs, knn_query
from repro.kernels import ref as kref

SETTINGS = dict(max_examples=15, deadline=None)


def _brute_allpairs(x, k, distance, exclude_self=True):
    D = np.array(kref.pairwise_distance_ref(x, x, distance=distance))
    if exclude_self:
        np.fill_diagonal(D, np.inf)
    order = np.argsort(D, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(D, order, axis=1), order


@pytest.mark.parametrize("distance", ["sqeuclidean", "neg_cosine", "hellinger"])
@pytest.mark.parametrize("impl", ["jnp", "pallas", "fused"])
def test_allpairs_matches_brute(distance, impl):
    if impl == "fused" and distance == "hellinger":
        pytest.skip("fused kernel covers MXU-form tiles; hellinger tested via pallas")
    g = np.random.default_rng(0)
    if distance == "hellinger":
        x = g.gamma(1.0, 1.0, (300, 64)).astype(np.float32) + 1e-4
        x /= x.sum(1, keepdims=True)
    else:
        x = g.standard_normal((300, 64), dtype=np.float32)
    x = jnp.asarray(x)
    k = 10
    res = knn_allpairs(x, k, distance=distance, gsize=128, impl=impl)
    ref_v, _ = _brute_allpairs(x, k, distance)
    np.testing.assert_allclose(np.asarray(res.distances), ref_v, atol=3e-3)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    m=st.integers(1, 80), n=st.integers(1, 150), d=st.integers(1, 40),
    k=st.integers(1, 24), seed=st.integers(0, 10_000),
)
def test_query_matches_brute(m, n, d, k, seed):
    g = np.random.default_rng(seed)
    q = jnp.asarray(g.standard_normal((m, d), dtype=np.float32))
    db = jnp.asarray(g.standard_normal((n, d), dtype=np.float32))
    res = knn_query(q, db, k, tile_m=32, tile_n=64)
    kk = min(k, n)
    D = np.asarray(kref.pairwise_distance_ref(q, db))
    ref = np.sort(D, axis=1)[:, :kk]
    np.testing.assert_allclose(np.asarray(res.distances)[:, :kk], ref, atol=1e-3)
    # returned indices must reproduce the distances
    got = np.take_along_axis(D, np.asarray(res.indices)[:, :kk], axis=1)
    np.testing.assert_allclose(got, ref, atol=1e-3)


def test_clustered_data_exercises_threshold_skip():
    """Clustered vectors (the recommender case): results identical with and
    without the heap-top threshold skip (Sect. 6 optimization is lossless)."""
    from repro.data.synthetic import clustered_vectors

    x = jnp.asarray(clustered_vectors(500, 32, n_clusters=10, seed=1))
    a = knn_allpairs(x, 15, gsize=128, threshold_skip=True)
    b = knn_allpairs(x, 15, gsize=128, threshold_skip=False)
    np.testing.assert_allclose(np.asarray(a.distances), np.asarray(b.distances),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))


def test_k_larger_than_n():
    g = np.random.default_rng(2)
    x = jnp.asarray(g.standard_normal((5, 8), dtype=np.float32))
    res = knn_allpairs(x, 100, gsize=128)
    assert res.distances.shape == (5, 4)  # k clamped to n-1 (self excluded)
    db = jnp.asarray(g.standard_normal((3, 8), dtype=np.float32))
    res = knn_query(x, db, 100)
    assert res.distances.shape == (5, 3)


def test_asymmetric_distance_uses_full_square():
    """KL is asymmetric: symmetric mode must not be silently applied."""
    g = np.random.default_rng(3)
    x = g.gamma(1.0, 1.0, (60, 16)).astype(np.float32) + 1e-4
    x /= x.sum(1, keepdims=True)
    x = jnp.asarray(x)
    res = knn_allpairs(x, 5, distance="kl", gsize=128)
    ref_v, _ = _brute_allpairs(x, 5, "kl")
    np.testing.assert_allclose(np.asarray(res.distances), ref_v, atol=1e-4)


def test_include_self():
    g = np.random.default_rng(4)
    x = jnp.asarray(g.standard_normal((50, 8), dtype=np.float32))
    res = knn_allpairs(x, 1, gsize=128, exclude_self=False)
    np.testing.assert_allclose(np.asarray(res.distances[:, 0]), 0.0, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.indices[:, 0]), np.arange(50))
