"""Train-loop fault tolerance: resume, NaN guard, straggler detection."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.loop import TrainLoop, TrainLoopConfig


def _counting_step(state, batch):
    return state + 1, {"loss": jnp.float32(1.0 / (float(state) + 1.0))}


def test_runs_to_total_and_checkpoints(tmp_path):
    loop = TrainLoop(_counting_step, lambda s: None,
                     TrainLoopConfig(total_steps=17, checkpoint_dir=str(tmp_path),
                                     checkpoint_every=5, log_every=5))
    st, end = loop.run(jnp.int32(0))
    assert end == 17 and int(st) == 17
    from repro.train.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 17


def test_auto_resume_continues(tmp_path):
    cfg = TrainLoopConfig(total_steps=10, checkpoint_dir=str(tmp_path),
                          checkpoint_every=5)
    TrainLoop(_counting_step, lambda s: None, cfg).run(jnp.int32(0))
    # "crash" happened; new process resumes from step 10 and trains to 20
    cfg2 = TrainLoopConfig(total_steps=20, checkpoint_dir=str(tmp_path),
                           checkpoint_every=5)
    loop2 = TrainLoop(_counting_step, lambda s: None, cfg2)
    st, end = loop2.run(jnp.int32(0))
    assert end == 20 and int(st) == 20
    # it did NOT replay steps 0-9
    assert len([h for h in loop2.history]) <= 4


def test_nan_guard_skips_then_aborts(tmp_path):
    calls = {"n": 0}

    def sometimes_nan(state, batch):
        calls["n"] += 1
        bad = calls["n"] in (3, 4)  # two isolated bad steps -> recovered
        return state + 1, {"loss": jnp.float32(float("nan") if bad else 1.0)}

    loop = TrainLoop(sometimes_nan, lambda s: None,
                     TrainLoopConfig(total_steps=10, max_bad_steps=3))
    st, end = loop.run(jnp.int32(0))
    assert end == 10
    assert int(st) == 8  # two updates skipped

    def always_nan(state, batch):
        return state, {"loss": jnp.float32(float("nan"))}

    loop2 = TrainLoop(always_nan, lambda s: None,
                      TrainLoopConfig(total_steps=100, max_bad_steps=4,
                                      checkpoint_dir=str(tmp_path)))
    with pytest.raises(FloatingPointError):
        loop2.run(jnp.int32(0))
    # a rescue checkpoint was written before aborting
    from repro.train.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is not None


def test_straggler_detection():
    def slow_every_7(state, batch):
        if int(state) % 7 == 6:
            time.sleep(0.08)
        else:
            time.sleep(0.002)
        return state + 1, {"loss": jnp.float32(1.0)}

    loop = TrainLoop(slow_every_7, lambda s: None,
                     TrainLoopConfig(total_steps=21, straggler_factor=5.0,
                                     straggler_warmup=3))
    loop.run(jnp.int32(0))
    assert len(loop.quarantine) >= 1
    assert all(q["dt"] > 5.0 * q["ewma"] for q in loop.quarantine)


def test_metrics_jsonl(tmp_path):
    import json

    path = str(tmp_path / "metrics.jsonl")
    loop = TrainLoop(_counting_step, lambda s: None,
                     TrainLoopConfig(total_steps=10, log_every=2,
                                     metrics_path=path))
    loop.run(jnp.int32(0))
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) >= 5
    assert all("loss" in r and "step" in r for r in recs)
