"""Minimal in-tree stand-in for ``hypothesis`` (used only when it is absent).

The property-test modules are written against real hypothesis (declared in the
``test`` extra of pyproject.toml — CI installs it).  The pinned container image
cannot install new packages, so ``conftest.install_hypothesis_fallback()``
registers this module under ``sys.modules["hypothesis"]`` when the import
fails.  It implements exactly the API surface the test-suite uses:

  * ``@hypothesis.settings(max_examples=..., deadline=..., suppress_health_check=...)``
  * ``@hypothesis.given(name=strategy, ...)`` (keyword strategies only)
  * ``hypothesis.HealthCheck.*``, ``hypothesis.assume``
  * ``strategies.integers / booleans / sampled_from``

Examples are drawn pseudo-randomly but deterministically (seeded per test
name), so failures reproduce run-to-run.  No shrinking, no database — this is
a sampler, not a replacement; CI still runs the real engine.
"""
from __future__ import annotations

import enum
import random
import sys
import types
import zlib

__version__ = "0.0-fallback"

_DEFAULT_MAX_EXAMPLES = 25


class HealthCheck(enum.Enum):
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


class _Unsatisfied(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2**31) if min_value is None else min_value
    hi = 2**31 - 1 if max_value is None else max_value
    return _Strategy(lambda rng: rng.randint(lo, hi))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def settings(*args, max_examples=_DEFAULT_MAX_EXAMPLES, **kwargs):
    """Decorator form only (the suite never uses settings profiles)."""
    del args, kwargs  # deadline / suppress_health_check: meaningless here

    def wrap(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return wrap


def given(**strategy_kwargs):
    def wrap(fn):
        import functools
        import inspect

        @functools.wraps(fn)
        def runner(*args, **fixture_kwargs):
            n = getattr(runner, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(max(4 * n, n + 8)):
                if ran >= n:
                    break
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **fixture_kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}): {drawn!r}"
                    ) from e
                ran += 1

        # Hide the drawn parameters from pytest (they are not fixtures).
        sig = inspect.signature(fn)
        runner.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs
        ])
        return runner

    return wrap


def _as_modules():
    """Build (hypothesis, hypothesis.strategies) module objects."""
    hyp = types.ModuleType("hypothesis")
    hyp.__version__ = __version__
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.booleans = booleans
    st.sampled_from = sampled_from
    hyp.strategies = st
    return hyp, st


def install() -> None:
    """Register the fallback under 'hypothesis' if the real one is missing."""
    try:
        import hypothesis  # noqa: F401  (the real engine wins when present)

        return
    except ImportError:
        pass
    hyp, st = _as_modules()
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
