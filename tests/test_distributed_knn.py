"""Multi-device kNN solvers + collectives (8 forced host devices, subprocess).

These are the paper's Sect. 4 claims: triangle/zigzag correctness, ring
correctness, per-device heaps merged once at the end, and scaling structure.
"""
from conftest import run_with_devices


def test_ring_and_triangle_match_oracle_8dev():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels import ref as kref
        np.random.seed(0)
        n, d, k = 1024, 48, 17
        x = jnp.asarray(np.random.randn(n, d).astype(np.float32))
        mesh = jax.make_mesh((8,), ("ring",), axis_types=(jax.sharding.AxisType.Auto,))
        Dm = np.array(kref.pairwise_distance_ref(x, x))
        np.fill_diagonal(Dm, np.inf)
        rv = np.sort(Dm, 1)[:, :k]
        for maker, kw in [
            (D.make_ring_allpairs, {}),
            (D.make_triangle_allpairs, dict(gsize=128)),
        ]:
            fn = maker(mesh, k=k, distance="sqeuclidean", **kw)
            res = fn(x, n)
            err = float(np.max(np.abs(np.asarray(res.distances) - rv)))
            assert err < 2e-3, (maker.__name__, err)
            # indices reproduce distances
            got = np.take_along_axis(Dm, np.asarray(res.indices), 1)
            assert np.allclose(got, rv, atol=2e-3)
        print("OK")
    """)


def test_ring_odd_vs_even_participants():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels import ref as kref
        np.random.seed(1)
        n, d, k = 512, 32, 9
        x = jnp.asarray(np.random.randn(n, d).astype(np.float32))
        Dm = np.array(kref.pairwise_distance_ref(x, x)); np.fill_diagonal(Dm, np.inf)
        rv = np.sort(Dm, 1)[:, :k]
        # P=8 (even) exercises the final half-step; P=4, P=2 sanity
        for P in (2, 4, 8):
            devs = jax.devices()[:P]
            mesh = jax.sharding.Mesh(np.array(devs), ("ring",))
            fn = D.make_ring_allpairs(mesh, k=k)
            res = fn(x, n)
            err = float(np.max(np.abs(np.asarray(res.distances) - rv)))
            assert err < 2e-3, (P, err)
        print("OK")
    """)


def test_query_sharded_2d_mesh():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels import ref as kref
        np.random.seed(2)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        q = jnp.asarray(np.random.randn(64, 32).astype(np.float32))
        db = jnp.asarray(np.random.randn(512, 32).astype(np.float32))
        for impl in ("jnp", "fused"):
            fn = D.make_query_sharded(mesh, query_axis="data", db_axis="model",
                                      k=11, impl=impl)
            res = fn(q, db, 512)
            Dm = np.asarray(kref.pairwise_distance_ref(q, db))
            rv = np.sort(Dm, 1)[:, :11]
            assert np.allclose(np.asarray(res.distances), rv, atol=2e-3), impl
        print("OK")
    """)


def test_ragged_database_masking():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels import ref as kref
        np.random.seed(3)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        q = jnp.asarray(np.random.randn(16, 16).astype(np.float32))
        db_pad = jnp.asarray(np.random.randn(512, 16).astype(np.float32))
        n_real = 300  # last shards partially / fully padding
        fn = D.make_query_sharded(mesh, query_axis="data", db_axis="model", k=7)
        res = fn(q, db_pad, n_real)
        Dm = np.asarray(kref.pairwise_distance_ref(q, db_pad[:n_real]))
        rv = np.sort(Dm, 1)[:, :7]
        assert np.allclose(np.asarray(res.distances), rv, atol=2e-3)
        assert (np.asarray(res.indices) < n_real).all()
        print("OK")
    """)


def test_tree_merge_topk_butterfly():
    run_with_devices("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import topk as T
        from repro.core.distributed import tree_merge_topk
        np.random.seed(4)
        mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
        vals = np.sort(np.random.randn(8, 16, 8).astype(np.float32), axis=-1)
        idx = np.random.randint(0, 1000, (8, 16, 8)).astype(np.int32)
        @functools.partial(jax.shard_map, mesh=mesh, in_specs=(P("x"), P("x")),
                           out_specs=(P("x"), P("x")), check_vma=False)
        def body(v, i):
            mv, mi = tree_merge_topk(v[0], i[0], "x")
            return mv[None], mi[None]
        mv, mi = body(jnp.asarray(vals), jnp.asarray(idx))
        ref = np.sort(vals.transpose(1, 0, 2).reshape(16, -1), axis=1)[:, :8]
        for d in range(8):
            assert np.allclose(np.asarray(mv)[d], ref), d
        print("OK")
    """)


def test_compressed_psum_tree():
    run_with_devices("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import compressed_psum_tree, init_error_state
        np.random.seed(5)
        mesh = jax.make_mesh((8,), ("dp",), axis_types=(jax.sharding.AxisType.Auto,))
        g = {"a": np.random.randn(8, 257).astype(np.float32),
             "b": np.random.randn(8, 4, 33).astype(np.float32)}
        e = {"a": np.zeros((8, 257), np.float32), "b": np.zeros((8, 4, 33), np.float32)}
        @functools.partial(jax.shard_map, mesh=mesh,
                           in_specs=({"a": P("dp"), "b": P("dp")},)*2,
                           out_specs=({"a": P("dp"), "b": P("dp")},)*2,
                           check_vma=False)
        def body(gl, el):
            s, ne = compressed_psum_tree(
                {k: v[0] for k, v in gl.items()},
                {k: v[0] for k, v in el.items()}, "dp")
            return ({k: v[None] for k, v in s.items()},
                    {k: v[None] for k, v in ne.items()})
        s, ne = body({k: jnp.asarray(v) for k, v in g.items()},
                     {k: jnp.asarray(v) for k, v in e.items()})
        for k in g:
            true = g[k].sum(0)
            approx = np.asarray(s[k])[0]
            rel = np.abs(approx - true).max() / (np.abs(true).max() + 1e-9)
            assert rel < 0.05, (k, rel)
        print("OK")
    """)
