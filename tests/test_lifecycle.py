"""repro.serving.lifecycle: WAL durability, torn-tail recovery, epoch handoff.

The contract under test (DESIGN.md §16):

* every mutation ack implies durability — the record is fsynced into the
  snapshot's ``journal.bin`` before ``insert``/``upsert``/``delete`` returns,
  and ``recover()`` replays every acked record after ANY crash point,
  including a SIGKILL mid-append (the torn in-flight frame is dropped at the
  last valid boundary; it was never acked);
* any byte-length crash prefix of the journal restores to EXACTLY the state
  after the last fully-acked record (the hypothesis property below);
* mid-file corruption is still refused — leniency applies only to the
  genuinely in-flight tail;
* ``compact()`` trains epoch N+1 in a background worker and the handed-off
  index is BIT-identical to a synchronous compact; no search ever enters
  ``core.kmeans.lloyd`` on the serving thread (tripwire-enforced);
* a mutation past ``delta_budget`` raises ``BackpressureError`` before
  anything is applied or logged.
"""
import os
import shutil
import signal
import struct
import subprocess
import sys
import tempfile
import threading
import time

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from repro.serving import (
    BackpressureError,
    EngineConfig,
    LifecycleConfig,
    LifecycleIndex,
    QueryEngine,
    RetrievalIndex,
    SnapshotError,
    WalWriter,
)
from repro.serving.snapshot import _JOURNAL, _JOURNAL_MAGIC_V1, read_manifest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CONFIGS = {
    "flat": {},
    "int8": {"scan_dtype": "int8"},
    "ivf": {"ivf_cells": 16, "nprobe": 4},
    "ivfpq": {"ivf_cells": 16, "nprobe": 8, "pq_m": 8},
}


def _base_index(kw, n=512, d=32, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    idx = RetrievalIndex.build(np.arange(n), vecs, **kw)
    q = rng.standard_normal((16, d)).astype(np.float32)
    return idx, q


def _churn(lc, n=512, d=32, seed=1):
    """Three acked batches: bulk insert, overlapping upsert, delete."""
    rng = np.random.default_rng(seed)
    lc.insert(np.arange(n, n + 32),
              rng.standard_normal((32, d)).astype(np.float32))
    # Overlap re-upserts inside the delta: dead + live rows under one id.
    lc.upsert(np.arange(n + 28, n + 40),
              rng.standard_normal((12, d)).astype(np.float32))
    lc.delete(np.arange(0, n, 19))


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances))


# -- WAL durability round-trip ------------------------------------------------


@pytest.mark.parametrize("name", list(CONFIGS))
def test_wal_recover_bit_identical(name, tmp_path):
    idx, q = _base_index(CONFIGS[name])
    snap = str(tmp_path / name)
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    _churn(lc)
    want = lc.search(q, 10)
    want_delta = (int(idx._delta_n), idx._delta_live[: idx._delta_n].copy())
    lc.close()

    lc2, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec.wal and rec.torn_bytes == 0
    assert rec.tail_records == 3  # every acked batch survived, none stamped
    got = lc2.index
    assert int(got._delta_n) == want_delta[0]
    np.testing.assert_array_equal(got._delta_live[: got._delta_n],
                                  want_delta[1])
    _assert_bit_identical(want, lc2.search(q, 10))
    lc2.close()


def test_vectorized_replay_rebuilds_exact_delta_state(tmp_path):
    """Bulk ADD replays as ONE vectorized append with identical internals."""
    idx, q = _base_index(CONFIGS["flat"])
    # Dead rows inside the saved delta journal: live-mask bits in the record.
    rng = np.random.default_rng(7)
    idx.upsert(np.arange(512, 512 + 48),
               rng.standard_normal((48, 32)).astype(np.float32))
    idx.upsert(np.arange(512, 512 + 6),
               rng.standard_normal((6, 32)).astype(np.float32))
    idx.delete([512 + 2, 512 + 40])
    snap = str(tmp_path / "snap")
    idx.save(snap, wal=True)
    got = RetrievalIndex.restore(snap)
    assert int(got._delta_n) == int(idx._delta_n)
    np.testing.assert_array_equal(got._delta_live[: got._delta_n],
                                  idx._delta_live[: idx._delta_n])
    assert got._loc == idx._loc
    _assert_bit_identical(idx.search(q, 10), got.search(q, 10))


# -- torn tail vs corruption --------------------------------------------------


def test_torn_tail_truncated_and_replay_resumes(tmp_path):
    idx, q = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    _churn(lc)
    want = lc.search(q, 10)
    lc.close()
    journal = os.path.join(snap, _JOURNAL)
    # Crash mid-append: a frame header claiming 1 MiB with 40 payload bytes.
    with open(journal, "ab") as f:
        f.write(struct.pack("<4sII", b"ADD\0", 1 << 20, 0) + b"\0" * 40)

    lc2, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec.torn_bytes == 12 + 40
    assert rec.tail_records == 3  # all acked records replayed
    # The torn frame is physically gone: the journal is back to a verified
    # frame boundary and appending resumes from there.
    assert os.path.getsize(journal) == rec.valid_bytes
    _assert_bit_identical(want, lc2.search(q, 10))
    lc2.insert([9000], np.ones((1, 32), np.float32))
    lc2.close()
    lc3, rec3 = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec3.torn_bytes == 0 and rec3.tail_records == 4
    assert 9000 in lc3
    lc3.close()


def test_corruption_inside_stamped_prefix_refused(tmp_path):
    idx, _ = _base_index(CONFIGS["flat"])
    rng = np.random.default_rng(2)
    idx.upsert(np.arange(512, 512 + 16),
               rng.standard_normal((16, 32)).astype(np.float32))
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    lc.close()
    journal = os.path.join(snap, _JOURNAL)
    stamp = read_manifest(snap, verify=False)["files"][_JOURNAL]["bytes"]
    assert stamp > 32  # the attach image journals the delta rows
    with open(journal, "r+b") as f:
        f.seek(stamp - 5)
        byte = f.read(1)
        f.seek(stamp - 5)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(SnapshotError):
        LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))


def test_corruption_mid_tail_refused_not_torn(tmp_path):
    """A CRC-failing tail frame WITH data after it is damage, not a crash."""
    idx, _ = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    stamp = lc._wal.tell()
    lc.insert([600], np.ones((1, 32), np.float32))
    end1 = lc._wal.tell()
    lc.insert([601], np.ones((1, 32), np.float32))
    lc.close()
    journal = os.path.join(snap, _JOURNAL)
    with open(journal, "r+b") as f:
        f.seek(end1 - 3)  # inside frame 1's payload; frame 2 follows
        byte = f.read(1)
        f.seek(end1 - 3)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(SnapshotError, match="CRC mismatch"):
        LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert stamp < end1  # sanity: the flip landed past the stamp


def test_journal_shorter_than_stamp_refused(tmp_path):
    idx, _ = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    lc.close()
    stamp = read_manifest(snap, verify=False)["files"][_JOURNAL]["bytes"]
    with open(os.path.join(snap, _JOURNAL), "r+b") as f:
        f.truncate(max(0, stamp - 1))
    with pytest.raises(SnapshotError):
        LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))


# -- property: every crash prefix restores the acked prefix -------------------

_N_ACKS = 8


@pytest.fixture(scope="module")
def wal_history(tmp_path_factory):
    """One journaled run: WAL boundaries + expected state after each ack."""
    snap = str(tmp_path_factory.mktemp("walprop") / "snap")
    idx, q = _base_index(CONFIGS["flat"], n=256, d=16, seed=3)
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    rng = np.random.default_rng(4)

    def state():
        r = lc.search(q, 8)
        return (int(lc.index._delta_n), np.asarray(r.distances).copy(),
                np.asarray(r.ids).copy())

    boundaries, states, nid = [lc._wal.tell()], [state()], 256
    for step in range(_N_ACKS):
        kind = step % 3
        if kind == 0:
            lc.insert(np.arange(nid, nid + 5),
                      rng.standard_normal((5, 16)).astype(np.float32))
            nid += 5
        elif kind == 1:
            lc.upsert(np.arange(nid - 3, nid + 2),
                      rng.standard_normal((5, 16)).astype(np.float32))
            nid += 2
        else:
            lc.delete(rng.integers(0, 256, size=4))
        boundaries.append(lc._wal.tell())
        states.append(state())
    lc.close()
    return snap, q, boundaries, states


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(i=st.integers(0, _N_ACKS), extra=st.integers(0, 1 << 30))
def test_any_crash_prefix_restores_acked_prefix(wal_history, i, extra):
    """Truncating the journal anywhere in [ack_i, ack_{i+1}) recovers state i.

    At a frame boundary (extra lands on 0) that is the exact acked-prefix
    restore; strictly inside the next frame it is a genuine torn tail — a
    literal crash prefix of the real byte stream — and the in-flight record
    must vanish without disturbing the acked prefix.
    """
    snap, q, boundaries, states = wal_history
    if i == _N_ACKS:
        cut = boundaries[i]
    else:
        cut = boundaries[i] + extra % (boundaries[i + 1] - boundaries[i])
    work = tempfile.mkdtemp()
    try:
        dst = os.path.join(work, "snap")
        shutil.copytree(snap, dst)
        with open(os.path.join(dst, _JOURNAL), "r+b") as f:
            f.truncate(cut)
        lc, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=dst))
        try:
            assert rec.tail_records == i
            assert rec.torn_bytes == cut - boundaries[i]
            delta_n, want_v, want_i = states[i]
            assert int(lc.index._delta_n) == delta_n
            got = lc.search(q, 8)
            np.testing.assert_array_equal(np.asarray(got.ids), want_i)
            np.testing.assert_array_equal(np.asarray(got.distances), want_v)
        finally:
            lc.close()
    finally:
        shutil.rmtree(work, ignore_errors=True)


# -- kill -9 mid-ingest -------------------------------------------------------

_KILL9_CHILD = """
import sys
import numpy as np
import repro  # noqa: F401 (jax API compat shims)
from repro.serving import LifecycleConfig, LifecycleIndex, RetrievalIndex

snap = sys.argv[1]
rng = np.random.default_rng(0)
vecs = rng.standard_normal((256, 32)).astype(np.float32)
idx = RetrievalIndex.build(np.arange(256), vecs)
lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
nid = 256
for i in range(200):
    lc.insert(np.arange(nid, nid + 4),
              rng.standard_normal((4, 32)).astype(np.float32))
    nid += 4
    print(f"ACK {i}", flush=True)  # printed strictly AFTER the fsync ack
"""


def test_kill9_mid_ingest_loses_no_acked_write(tmp_path):
    """SIGKILL a journaling writer; recovery == a never-crashed twin.

    The child prints ``ACK i`` only after insert ``i``'s fsync returned, so
    every ack the parent observes MUST survive.  The recovered index must
    also be bit-identical to a twin that applied exactly the replayed prefix
    of the same deterministic schedule and never crashed.
    """
    snap = str(tmp_path / "snap")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _KILL9_CHILD, snap],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
    acked = []
    try:
        deadline = time.monotonic() + 300
        while len(acked) < 3:
            line = proc.stdout.readline()
            if not line:
                break
            if line.startswith("ACK "):
                acked.append(int(line.split()[1]))
            assert time.monotonic() < deadline, "child produced no acks"
        proc.kill()  # SIGKILL: no atexit, no flush, no close
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.stdout.close()
        proc.stderr.close()
    assert acked and acked == list(range(len(acked)))

    lc, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    r = rec.tail_records
    assert r >= len(acked), (r, acked)  # no acked write lost

    # Never-crashed twin: replay the same deterministic schedule prefix.
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((256, 32)).astype(np.float32)
    twin = RetrievalIndex.build(np.arange(256), vecs)
    nid = 256
    for _ in range(r):
        twin.insert(np.arange(nid, nid + 4),
                    rng.standard_normal((4, 32)).astype(np.float32))
        nid += 4
    assert len(lc) == len(twin)
    q = np.random.default_rng(99).standard_normal((24, 32)).astype(np.float32)
    _assert_bit_identical(twin.search(q, 10), lc.search(q, 10))
    lc.close()


def test_kill9_crash_restart_with_sigkill_signal(tmp_path):
    """Same kill-9 recovery through the POSIX signal (not Popen.kill)."""
    snap = str(tmp_path / "snap")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _KILL9_CHILD, snap],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
    try:
        line = proc.stdout.readline()
        while line and not line.startswith("ACK 1"):
            line = proc.stdout.readline()
        assert line, "child never acked"
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.stdout.close()
        proc.stderr.close()
    lc, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec.tail_records >= 2  # acks 0 and 1 were both observed
    assert len(lc) == 256 + 4 * rec.tail_records
    lc.close()


# -- background retrain + epoch handoff ---------------------------------------


@pytest.mark.parametrize("name", ["ivf", "ivfpq"])
def test_background_handoff_bit_identical_to_sync_compact(name, tmp_path):
    idx, q = _base_index(CONFIGS[name])
    twin, _ = _base_index(CONFIGS[name])  # same seed: identical build
    snap = str(tmp_path / name)
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    _churn(lc)
    rng = np.random.default_rng(1)
    twin.insert(np.arange(512, 512 + 32),
                rng.standard_normal((32, 32)).astype(np.float32))
    twin.upsert(np.arange(512 + 28, 512 + 40),
                rng.standard_normal((12, 32)).astype(np.float32))
    twin.delete(np.arange(0, 512, 19))

    twin.compact()  # blocking repack; first search trains synchronously
    want = twin.search(q, 10)
    lc.compact(wait=True)  # background worker trains, then swaps
    assert lc.stats()["epoch"] == twin._main_epoch
    assert lc.stats()["handoffs"] == 1
    _assert_bit_identical(want, lc.search(q, 10))
    lc.close()


def test_mutations_during_pending_window_survive_handoff(tmp_path):
    idx, q = _base_index(CONFIGS["ivf"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    _churn(lc)
    lc.compact()  # cut taken; worker training in the background
    # Post-cut mutations land on epoch N and the WAL...
    lc.insert([7001], np.full((1, 32), 0.5, np.float32))
    lc.delete([1])
    assert lc.finish_handoff(wait=True)
    # ...and must ride the handoff onto epoch N+1.
    assert 7001 in lc and 1 not in lc
    assert lc.stats()["delta_rows"] == 1  # just the post-cut insert
    want = lc.search(q, 10)
    lc.close()
    # Crash right after the swap: the new image + copied tail recover.
    lc2, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert 7001 in lc2 and 1 not in lc2
    _assert_bit_identical(want, lc2.search(q, 10))
    lc2.close()


def test_serving_thread_never_trains(tmp_path, monkeypatch):
    """The Lloyd tripwire: handoff training happens OFF the serving thread."""
    import repro.core.kmeans as KM

    idx, q = _base_index(CONFIGS["ivf"])
    idx.search(q, 10)  # train the initial epoch before arming
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))

    real, calls = KM.lloyd, []

    def guard(*a, **kw):
        assert threading.current_thread() is not threading.main_thread(), (
            "kmeans.lloyd entered on the serving thread")
        calls.append(threading.current_thread().name)
        return real(*a, **kw)

    monkeypatch.setattr(KM, "lloyd", guard)
    # train_cells is jitted: a same-shape trace from an earlier test would
    # skip its Python body (and the guard) entirely — force a retrace.
    import jax

    jax.clear_caches()
    _churn(lc)
    lc.compact(wait=True)
    assert calls, "background worker never trained"
    lc.search(q, 10)  # steady-state serving after the swap
    lc.close()


def test_sync_train_tripwire_raises_instead_of_stalling(tmp_path):
    idx, q = _base_index(CONFIGS["ivf"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    # Bypass the lifecycle: a raw compact strands the epoch untrained, and
    # the next search would train synchronously — the tripwire fires.
    lc.index.compact()
    with pytest.raises(RuntimeError, match="tripwire"):
        lc.search(q, 10)
    lc.close()


def test_engine_swaps_ready_epoch_at_batch_boundary(tmp_path):
    idx, q = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    eng = QueryEngine(lc, EngineConfig(k=8, min_batch=8, max_batch=64))
    eng.search(q, 8)
    _churn(lc)
    epoch0 = lc.stats()["epoch"]
    lc.compact()  # no wait: the swap must come from the engine hook
    deadline = time.monotonic() + 120
    while lc.stats()["state"] == "train":
        assert time.monotonic() < deadline, "worker never finished"
        time.sleep(0.01)
    assert lc.stats()["state"] == "handoff"
    assert lc.stats()["epoch"] == epoch0  # not swapped yet: no batch ran
    r = eng.search(q, 8)  # before_batch hook swaps, then the batch serves
    assert lc.stats()["state"] == "serve"
    assert lc.stats()["epoch"] == epoch0 + 1
    _assert_bit_identical(r, lc.search(q, 8))
    lc.close()


# -- admission control --------------------------------------------------------


def test_backpressure_applies_nothing_and_logs_nothing(tmp_path):
    idx, _ = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(
        idx, LifecycleConfig(snapshot_dir=snap, delta_budget=16))
    rng = np.random.default_rng(5)
    lc.insert(np.arange(512, 512 + 16),
              rng.standard_normal((16, 32)).astype(np.float32))
    tell0, delta0 = lc._wal.tell(), int(lc.index._delta_n)
    with pytest.raises(BackpressureError, match="budget"):
        lc.insert([9000], np.ones((1, 32), np.float32))
    assert lc._wal.tell() == tell0  # nothing logged
    assert int(lc.index._delta_n) == delta0  # nothing applied
    assert 9000 not in lc
    assert lc.stats()["rejected"] == 1
    lc.delete([512])  # deletes are always admitted: they free space
    lc.compact(wait=True)
    lc.insert([9000], np.ones((1, 32), np.float32))  # budget drained
    assert 9000 in lc
    lc.close()


# -- incremental checkpoint ---------------------------------------------------


def test_checkpoint_extends_stamp_without_rewriting_main(tmp_path):
    idx, q = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    main = os.path.join(snap, "main.npz")
    st0 = os.stat(main)
    _churn(lc)
    lc.checkpoint()
    st1 = os.stat(main)
    assert (st0.st_mtime_ns, st0.st_size) == (st1.st_mtime_ns, st1.st_size)
    stamp = read_manifest(snap, verify=False)["files"][_JOURNAL]["bytes"]
    assert stamp == lc._wal.tell()  # the whole tail is now verified prefix
    want = lc.search(q, 10)
    lc.close()
    lc2, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec.tail_records == 0 and rec.prefix_records >= 3
    _assert_bit_identical(want, lc2.search(q, 10))
    lc2.close()


def test_checkpoint_refuses_rebased_main(tmp_path):
    idx, _ = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    lc = LifecycleIndex.attach(idx, LifecycleConfig(snapshot_dir=snap))
    lc._dirty_main = True  # the guard a sync compact arms mid-flight
    with pytest.raises(SnapshotError, match="full"):
        lc.checkpoint()
    lc.close()


# -- format upgrades ----------------------------------------------------------


def test_recover_upgrades_non_wal_snapshot(tmp_path):
    idx, q = _base_index(CONFIGS["flat"])
    snap = str(tmp_path / "snap")
    idx.save(snap)  # plain §Persistence image: no WAL marker
    assert not read_manifest(snap, verify=False).get("wal")
    lc, rec = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert not rec.wal  # forensics report what was found...
    assert read_manifest(snap, verify=False)["wal"]  # ...upgrade re-stamped
    lc.insert([9000], np.ones((1, 32), np.float32))
    want = lc.search(q, 10)
    lc.close()
    lc2, rec2 = LifecycleIndex.recover(LifecycleConfig(snapshot_dir=snap))
    assert rec2.wal and rec2.tail_records == 1
    _assert_bit_identical(want, lc2.search(q, 10))
    lc2.close()


def test_walwriter_refuses_v1_journal(tmp_path):
    path = str(tmp_path / "journal.bin")
    with open(path, "wb") as f:
        f.write(_JOURNAL_MAGIC_V1)
    with pytest.raises(SnapshotError, match="magic"):
        WalWriter(path)


# -- service integration ------------------------------------------------------


def test_service_lifecycle_end_to_end(tmp_path):
    import jax

    from repro.configs import registry as REG
    from repro.models.nn import split_params
    from repro.serving import ServiceConfig, TwoTowerRetrievalService

    arch = REG.get("two-tower-retrieval")
    cfg = arch.smoke_config()
    values, _ = split_params(arch.init_params(jax.random.PRNGKey(0), cfg))
    snap = str(tmp_path / "snap")
    sc = ServiceConfig(k=5, snapshot_dir=snap, wal=True, delta_budget=64)
    svc = TwoTowerRetrievalService(values, cfg, sc)

    rng = np.random.default_rng(1)
    n = 256
    fields = rng.integers(0, min(cfg.i_sizes()),
                          size=(n, cfg.n_item_fields)).astype(np.int32)
    svc.build_corpus(np.arange(n), fields)
    svc.enable_lifecycle()
    new_fields = rng.integers(0, min(cfg.i_sizes()),
                              size=(24, cfg.n_item_fields)).astype(np.int32)
    svc.ingest_items(np.arange(n, n + 24), new_fields)
    svc.delete_items(np.arange(0, n, 31))
    svc.compact(wait=True)
    assert svc.stats()["lifecycle"]["handoffs"] == 1
    ukeys = np.arange(7)
    ufields = rng.integers(0, min(cfg.u_sizes()),
                           size=(7, cfg.n_user_fields)).astype(np.int32)
    want_ids, want_scores = svc.recommend(ukeys, ufields)

    # Crash-restart: a fresh service recovers snapshot + WAL and serves
    # bit-identically.
    svc2 = TwoTowerRetrievalService(values, cfg, sc)
    rec = svc2.recover_lifecycle()
    assert rec.wal and rec.torn_bytes == 0
    got_ids, got_scores = svc2.recommend(ukeys, ufields)
    np.testing.assert_array_equal(want_ids, got_ids)
    np.testing.assert_array_equal(want_scores, got_scores)

    # Mismatched tower params must be refused, exactly as restore_index.
    values2, _ = split_params(arch.init_params(jax.random.PRNGKey(1), cfg))
    svc3 = TwoTowerRetrievalService(values2, cfg, sc)
    with pytest.raises(SnapshotError, match="different model"):
        svc3.recover_lifecycle()
