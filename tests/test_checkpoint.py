"""Checkpointing: atomicity, torn-save recovery, GC, async, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.train.checkpoint import (CheckpointManager, available_steps,
                                    latest_step, restore, save)


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": [jnp.ones((2, 2), jnp.bfloat16), jnp.int32(7)],
            "c": {"d": jnp.zeros((5,), jnp.int8)}}


def test_roundtrip_preserves_values_and_dtypes(tmp_path):
    t = _tree()
    save(str(tmp_path), t, 3)
    out, step, _ = restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_skips_torn_checkpoint(tmp_path):
    t = _tree()
    save(str(tmp_path), t, 1)
    save(str(tmp_path), t, 2)
    # tear step 2 three different ways; each must fall back to step 1
    d2 = tmp_path / "step_00000002"
    (d2 / "manifest.json").unlink()
    assert latest_step(str(tmp_path)) == 1
    save(str(tmp_path), t, 2)
    (d2 / "leaves.npz").unlink()
    assert latest_step(str(tmp_path)) == 1
    save(str(tmp_path), t, 2)
    with open(d2 / "manifest.json", "w") as f:
        f.write("{not json")
    assert latest_step(str(tmp_path)) == 1


def test_save_is_atomic_wrt_existing(tmp_path):
    t = _tree()
    save(str(tmp_path), t, 1)
    # a stale tmp dir from a crashed writer must not be visible
    os.makedirs(tmp_path / "step_00000005.tmp-999")
    assert latest_step(str(tmp_path)) == 1


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20, 30, 40):
        mgr.save(t, s)
    mgr.wait()
    assert available_steps(str(tmp_path)) == [30, 40]


def test_extra_metadata_roundtrip(tmp_path):
    save(str(tmp_path), _tree(), 7, extra={"loss": 1.5, "arch": "yi-6b"})
    _, _, extra = restore(str(tmp_path), jax.eval_shape(_tree))
    assert extra == {"loss": 1.5, "arch": "yi-6b"}


def test_leaf_count_mismatch_rejected(tmp_path):
    save(str(tmp_path), _tree(), 1)
    with pytest.raises(AssertionError):
        restore(str(tmp_path), jax.eval_shape(lambda: {"a": jnp.zeros((3, 4))}))


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written on an 8-device mesh restores onto 2 and 4 device
    meshes with different shardings — the elastic-scaling requirement."""
    run_with_devices(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import save, restore
        path = {str(tmp_path)!r}

        mesh8 = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data")))
        save(path, {{"w": w}}, 11)

        for n in (2, 4):
            devs = jax.devices()[:n]
            mesh = jax.sharding.Mesh(np.array(devs), ("data",))
            shd = {{"w": NamedSharding(mesh, P("data"))}}
            out, step, _ = restore(path, {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}},
                                   shardings=shd)
            assert step == 11
            assert np.allclose(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
            assert len(out["w"].sharding.device_set) == n
        print("OK")
    """)


def test_train_state_checkpoint_roundtrip(tmp_path, rules):
    """Full TrainState (params + opt moments) through save/restore."""
    from repro.distributed import steps as ST
    from repro.models import transformer as Tr

    cfg = Tr.TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                               head_dim=16, d_ff=64, vocab=64, dtype=jnp.float32)
    params = Tr.init_params(jax.random.PRNGKey(0), cfg)
    loss, baxes = ST.lm_loss(cfg)
    _, jitted, _, opt = ST.make_train_step(
        loss, Tr.abstract_params(cfg), rules, baxes, ST.StepConfig())
    state = ST.init_state(opt, params)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    fn = jitted(batch)
    state, _ = fn(state, batch)
    save(str(tmp_path), state, 1)
    like = jax.eval_shape(lambda: state)
    out, _, _ = restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
