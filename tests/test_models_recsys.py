"""RecSys substrate: per-arch smoke + EmbeddingBag/CIN correctness."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as REG
from repro.data.synthetic import recsys_batch
from repro.models import recsys as R

RECSYS_ARCHS = ["dlrm-rm2", "xdeepfm", "bst", "two-tower-retrieval"]


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_arch_smoke_train(arch_id, rules):
    from repro.distributed import steps as ST

    arch = REG.get(arch_id)
    cfg = arch.smoke_config()
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    loss, baxes = ST.recsys_loss(arch_id, cfg)
    _, jitted, _, opt = ST.make_train_step(
        loss, arch.abstract_params(cfg), rules, baxes,
        ST.StepConfig(peak_lr=5e-3, warmup_steps=5, total_steps=100))
    state = ST.init_state(opt, params)
    b0 = {k: jnp.asarray(v) for k, v in recsys_batch(arch_id, 64, cfg).items()}
    fn = jitted(b0)
    first = last = None
    for i in range(15):
        b = {k: jnp.asarray(v) for k, v in
             recsys_batch(arch_id, 64, cfg, step=i).items()}
        state, m = fn(state, b)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert np.isfinite(last)
    assert last < first, (first, last)


def test_embedding_bag_modes():
    t = R.init_table(jax.random.PRNGKey(0), 50, 8)
    ids = jnp.array([1, 2, 3, 10, 11, 40])
    bags = jnp.array([0, 0, 1, 1, 1, 3])
    out = R.embedding_bag(t, ids, bags, 4)
    tv = t.value
    ref = jnp.stack([tv[1] + tv[2], tv[3] + tv[10] + tv[11],
                     jnp.zeros(8), tv[40]])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    mean = R.embedding_bag(t, ids, bags, 4, mode="mean")
    np.testing.assert_allclose(np.asarray(mean[0]), np.asarray((tv[1] + tv[2]) / 2),
                               atol=1e-6)
    # weighted
    w = jnp.array([2.0, 0.0, 1.0, 1.0, 1.0, 3.0])
    wout = R.embedding_bag(t, ids, bags, 4, weights=w)
    np.testing.assert_allclose(np.asarray(wout[0]), np.asarray(2 * tv[1]), atol=1e-6)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    nnz=st.integers(1, 64), n_bags=st.integers(1, 8), seed=st.integers(0, 1000)
)
def test_embedding_bag_property(nnz, n_bags, seed):
    """segment_sum formulation == dense one-hot matmul oracle."""
    g = np.random.default_rng(seed)
    t = R.init_table(jax.random.PRNGKey(seed), 20, 4)
    ids = g.integers(0, 20, nnz).astype(np.int32)
    bags = np.sort(g.integers(0, n_bags, nnz)).astype(np.int32)
    out = R.embedding_bag(t, jnp.asarray(ids), jnp.asarray(bags), n_bags)
    onehot = np.zeros((n_bags, nnz), np.float32)
    onehot[bags, np.arange(nnz)] = 1.0
    ref = onehot @ np.asarray(t.value)[ids]
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


def test_cin_matches_reference():
    """CIN einsum == explicit outer-product formulation (xDeepFM eq. 4)."""
    B, F, D, H = 3, 5, 4, 7
    g = jax.random.PRNGKey(0)
    x0 = jax.random.normal(g, (B, F, D))
    W = jax.random.normal(jax.random.fold_in(g, 1), (H, F, F))
    fast = jnp.einsum("bid,bjd,hij->bhd", x0, x0, W)
    # explicit: z[b,h,d] = sum_ij W[h,i,j] * x0[b,i,d] * x0[b,j,d]
    z = jnp.zeros((B, H, D))
    for i in range(F):
        for j in range(F):
            z = z + W[:, i, j][None, :, None] * (x0[:, i, :] * x0[:, j, :])[:, None, :]
    np.testing.assert_allclose(np.asarray(fast), np.asarray(z), atol=1e-4)


def test_dlrm_interaction_is_upper_triangle():
    cfg = R.DLRMConfig(n_dense=4, n_sparse=3, embed_dim=8,
                       bot_mlp=(8,), top_mlp=(4, 1),
                       table_sizes=(16, 16, 16))
    p = R.init_dlrm(jax.random.PRNGKey(0), cfg)
    batch = {"dense": jnp.ones((2, 4)), "sparse": jnp.zeros((2, 3), jnp.int32)}
    out = R.dlrm_logits(p, batch, cfg)
    assert out.shape == (2,)
    # feature count into top mlp: F(F-1)/2 + D with F = n_sparse+1 = 4
    assert p["top"][0]["w"].value.shape[0] == 6 + 8


def test_two_tower_embeddings_normalized():
    cfg = R.TwoTowerConfig(user_sizes=(64,) * 6, item_sizes=(64,) * 4,
                           tower_mlp=(16, 8), feat_dim=4)
    p = R.init_two_tower(jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (10, 6), 0, 64)
    u = R.user_embedding(p, ids)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(u, axis=-1)), 1.0,
                               atol=1e-5)


def test_bce_loss_extremes():
    loss0, _ = R.bce_loss(jnp.array([100.0]), jnp.array([1.0]))
    assert float(loss0) < 1e-4
    loss1, _ = R.bce_loss(jnp.array([-100.0]), jnp.array([1.0]))
    assert float(loss1) > 50
    # symmetric
    a, _ = R.bce_loss(jnp.array([2.0]), jnp.array([0.0]))
    b, _ = R.bce_loss(jnp.array([-2.0]), jnp.array([1.0]))
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)
