"""Fault-tolerant shard fleet: failover, deadlines, degraded serving, chaos.

The contract under test (DESIGN.md §14):

* **failover is bit-invisible** — with R=2 replicas and one worker of every
  shard permanently dead, ``ShardRouter.search`` returns results
  bit-identical to the healthy fleet (replicas serve identical data; the
  merge is keyed on shard position, not on which replica computed);
* **degradation is explicit, never silent** — with ALL replicas of a shard
  dead, ``degraded="refuse"`` raises a structured ``ShardUnavailableError``
  (offending cells, shard ids, per-replica attempts) and
  ``degraded="partial"`` serves the survivors with per-query ``coverage``
  < 1 and per-shard status on the ``SearchResult``;
* **the call path heals** — transient failures retry with backoff inside
  the attempt budget; replies landing past the deadline are discarded and
  counted as failures; torn/garbage replies are caught by result
  validation and fail over exactly like raised errors; per-worker health
  walks healthy → degraded → ejected → probation → healthy;
* **chaos is reproducible** — the seeded ``FaultPolicy`` schedule plus the
  ``VirtualClock`` make every test here deterministic bit-for-bit;
* **assembly reports everything** — a torn ``save_shards`` root (mixed
  parent fingerprints) raises ONE ``SnapshotError`` naming every
  inconsistent shard, not just the first.
"""
import json
import os
import shutil
from types import SimpleNamespace

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as T
from repro.serving import (CallPolicy, FaultInjectionError, FaultPolicy,
                           FaultyWorker,
                           HealthConfig, HealthState, HealthTracker,
                           MissingShardError, RetrievalIndex, ShardRouter,
                           ShardUnavailableError, SnapshotError,
                           TornResultError, VirtualClock, aggregate_topk,
                           inject_faults, load_fleet, load_router,
                           read_fleet_manifest, run_with_failover,
                           validate_run)
from repro.accounting import ServingMeter, replicated_fleet_model
from repro.data.synthetic import clustered_vectors
from repro.serving.faults import GARBAGE_KINDS, _garbage_result
from repro.serving.snapshot import save_shards, shard_dirs

N, D, K, NCELLS, NSHARDS = 1024, 16, 10, 8, 4
CFG = dict(ivf_cells=NCELLS, nprobe=4, overfetch=8)


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """One IVF index, its R=2 shard fleet root, and the healthy baseline."""
    vecs = clustered_vectors(N, D, seed=5)
    idx = RetrievalIndex.build(np.arange(N), vecs, **CFG)
    q = clustered_vectors(24, D, seed=6)
    root = str(tmp_path_factory.mktemp("faults") / "fleet")
    save_shards(idx, root, NSHARDS, replicas=2)
    base = load_fleet(root, replicas=1).search(q, K)
    return SimpleNamespace(idx=idx, vecs=vecs, q=q, root=root, base=base)


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances))


def _router(root, *, replicas=1, degraded="refuse", policy=None, vc=None,
            meter=None, kill=(), fault=None):
    """A fleet router on a VirtualClock, optionally with workers killed
    (permanent death from call 0) or given a specific FaultPolicy."""
    vc = vc if vc is not None else VirtualClock()
    policy = policy if policy is not None else CallPolicy()
    r = load_fleet(root, replicas=replicas, degraded=degraded,
                   call_policy=policy, meter=meter, clock=vc.now,
                   sleep=vc.sleep)
    if kill or fault:
        workers = []
        for w in r.workers:
            if w.key in kill:
                workers.append(FaultyWorker(w, FaultPolicy.die_at(0),
                                            clock=vc))
            elif fault is not None and w.key in fault:
                workers.append(FaultyWorker(w, fault[w.key], clock=vc))
            else:
                workers.append(w)
        r = ShardRouter(workers, strict=True, degraded=degraded,
                        call_policy=policy, meter=meter, clock=vc.now,
                        sleep=vc.sleep)
    return r, vc


# -- the headline: replica failover is bit-invisible -------------------------


def test_failover_bit_identical_with_one_replica_killed(fleet):
    """R=2, replica 0 of EVERY shard permanently dead: every query still
    returns bits identical to the healthy fleet — failover, zero
    degradation — and the dead workers end up ejected."""
    kill = {f"s{s}r0" for s in range(NSHARDS)}
    router, _ = _router(fleet.root, replicas=2, kill=kill)
    got = router.search(fleet.q, K)
    _assert_bit_identical(fleet.base, got)
    assert np.all(np.asarray(got.coverage) == 1.0)
    assert all(st_ in ("ok", "skipped") for _, st_ in got.shard_status)
    # Hammer it: repeated batches keep failing over, bits never move.
    for _ in range(3):
        _assert_bit_identical(fleet.base, router.search(fleet.q, K))
    # The dead replicas are out of the serving rotation (degraded or
    # ejected — once degraded, health rank routes around them, so they may
    # never accumulate to the ejection bar); the survivors stay healthy.
    h = router.health.summary()
    assert all(h[k]["state"] in ("degraded", "ejected")
               for k in kill if k in h)
    assert all(h[f"s{s}r1"]["state"] == "healthy" for s in range(NSHARDS)
               if f"s{s}r1" in h)


def test_healthy_fleet_reports_full_coverage(fleet):
    router, _ = _router(fleet.root, replicas=2)
    got = router.search(fleet.q, K)
    _assert_bit_identical(fleet.base, got)
    cov = np.asarray(got.coverage)
    assert cov.shape == (len(fleet.q),) and np.all(cov == 1.0)
    assert dict(got.shard_status).keys() == set(range(NSHARDS))


# -- degraded serving: refuse vs partial -------------------------------------


def test_all_replicas_dead_refuse_raises_structured(fleet):
    """Both replicas of shard 1 dead + degraded="refuse": the structured
    error names the shard, the probed cells, and the failover attempts."""
    router, _ = _router(fleet.root, replicas=2, kill={"s1r0", "s1r1"})
    with pytest.raises(ShardUnavailableError) as ei:
        router.search(fleet.q, K)
    e = ei.value
    assert e.shard_ids == (1,)
    lo, hi = router.workers[router.groups[1][0]].spec.cell_lo, \
        router.workers[router.groups[1][0]].spec.cell_hi
    assert e.cells and all(lo <= c < hi for c in e.cells)
    assert len(e.attempts) >= 2  # both replicas were actually tried
    assert {a.worker for a in e.attempts} == {"s1r0", "s1r1"}
    assert all(a.error for a in e.attempts)
    assert isinstance(e, MissingShardError)  # callers catch one type


def test_all_replicas_dead_partial_serves_with_coverage(fleet):
    router, _ = _router(fleet.root, replicas=2, degraded="partial",
                        kill={"s1r0", "s1r1"})
    got = router.search(fleet.q, K)
    cov = np.asarray(got.coverage)
    assert cov.shape == (len(fleet.q),)
    assert cov.min() < 1.0  # some query probed the dead shard's cells
    assert dict(got.shard_status)[1] == "failed"
    # Coverage is per query: a query probing only surviving cells is whole.
    probe = router.probe(fleet.q)
    gid, _ = router._group_of(probe)
    untouched = ~np.any(gid == 1, axis=1)
    if untouched.any():
        assert np.all(cov[untouched] == 1.0)
        np.testing.assert_array_equal(
            np.asarray(got.ids)[untouched],
            np.asarray(fleet.base.ids)[untouched])
    # Served neighbors are exactly the merge of the surviving shards: every
    # returned id must come from a live shard's cell range (or be -1 pad).
    ids = np.asarray(got.ids)
    assert np.all((ids >= -1) & (ids < N))


def test_degraded_policy_validated(fleet):
    with pytest.raises(ValueError, match="degraded"):
        load_fleet(fleet.root, degraded="shrug")


def test_unowned_cells_structured_error(fleet):
    """strict=False with a missing shard: the refuse path names the
    unowned cells (satellite: structured context on MissingShardError)."""
    dirs = shard_dirs(fleet.root)
    router = load_router(dirs[:-1], strict=False)
    with pytest.raises(MissingShardError, match="owned by no loaded shard") \
            as ei:
        router.search(fleet.q, K)
    missing = router.workers[-1].spec  # last loaded shard is s2; s3 absent
    assert ei.value.cells  # the offending cell ids ride on the error
    assert all(c >= missing.cell_hi for c in ei.value.cells)


# -- the call path: retries, deadlines, torn results -------------------------


def test_transient_failures_recover_via_retry(fleet):
    """fail-next-2 on a single-replica shard: the bounded retry loop eats
    both failures and the result is bit-identical; health walks
    DEGRADED -> HEALTHY on the following successes."""
    meter = ServingMeter()
    router, _ = _router(fleet.root, replicas=1, meter=meter,
                        fault={"s0r0": FaultPolicy.fail_next(2)})
    got = router.search(fleet.q, K)
    _assert_bit_identical(fleet.base, got)
    assert np.all(np.asarray(got.coverage) == 1.0)
    sh = meter.shard_summary()["workers"]["s0r0"]
    assert sh["calls"] == 3 and sh["failures"] == 2
    assert "FaultInjectionError" in sh["last_error"]
    assert router.health.state("s0r0") is HealthState.DEGRADED
    router.search(fleet.q, K)
    router.search(fleet.q, K)
    assert router.health.state("s0r0") is HealthState.HEALTHY


def test_garbage_replies_fail_over_like_errors(fleet):
    """Every torn-result flavor must be caught by validate_run on the
    dispatch path — a garbage reply retries and the final bits are
    healthy, never the garbage."""
    for kind in GARBAGE_KINDS:
        meter = ServingMeter()
        router, _ = _router(fleet.root, replicas=1, meter=meter,
                            fault={"s2r0": FaultPolicy.garbage(kind)})
        got = router.search(fleet.q, K)
        _assert_bit_identical(fleet.base, got)
        sh = meter.shard_summary()["workers"]["s2r0"]
        assert sh["failures"] == 1 and "TornResultError" in sh["last_error"]


def test_validate_run_catches_each_garbage_kind():
    m, Kp = 3, T.next_pow2(K)
    for kind in GARBAGE_KINDS:
        with pytest.raises(TornResultError):
            validate_run(_garbage_result(kind, m, Kp), m, Kp)
    # A legitimate padded run passes.
    from repro.core.knn import KNNResult

    ok = KNNResult(jnp.broadcast_to(jnp.arange(Kp, dtype=jnp.float32),
                                    (m, Kp)),
                   jnp.zeros((m, Kp), jnp.int32))
    assert validate_run(ok, m, Kp) is ok


def test_deadline_discards_late_reply():
    """A reply landing after the budget is a failure — discarded, recorded
    against the worker — even though the thunk 'succeeded'."""
    vc = VirtualClock()
    tracker = HealthTracker()

    def slow():
        vc.advance(0.1)
        return "late"

    out, attempts = run_with_failover(
        [("w", slow)], policy=CallPolicy(deadline_s=0.05, max_attempts=3),
        tracker=tracker, clock=vc.now, sleep=vc.sleep)
    assert out is None
    assert len(attempts) == 1 and attempts[0].error == "deadline exceeded"
    assert tracker.state("w") is HealthState.DEGRADED


def test_deadline_budget_stops_backoff():
    """Backoff that cannot fit the remaining budget is not slept."""
    vc = VirtualClock()
    calls = []

    def failing():
        calls.append(vc.now())
        raise RuntimeError("nope")

    policy = CallPolicy(deadline_s=0.001, max_attempts=10,
                        backoff_base_s=0.01, jitter_frac=0.0)
    out, attempts = run_with_failover([("w", failing)], policy=policy,
                                      tracker=HealthTracker(),
                                      clock=vc.now, sleep=vc.sleep)
    assert out is None
    assert len(attempts) == 1  # attempt 2's 10ms backoff breaks the budget
    assert vc.now() == 0.0  # and was never slept


def test_latency_spike_fails_batch_then_routes_around(fleet):
    """Replica 0 of shard 0 answers 50ms late against a 40ms deadline: the
    first batch loses shard 0 (late reply discarded), and the NEXT batch
    routes to the healthy replica first — full coverage, healthy bits."""
    vc = VirtualClock()
    policy = CallPolicy(deadline_s=0.04, max_attempts=4)
    router, vc = _router(fleet.root, replicas=2, degraded="partial",
                         policy=policy, vc=vc,
                         fault={"s0r0": FaultPolicy.latency(0.05)})
    got = router.search(fleet.q, K)
    assert dict(got.shard_status)[0] == "failed"
    assert np.asarray(got.coverage).min() < 1.0
    assert router.health.state("s0r0") is HealthState.DEGRADED
    # Next batch: health rank puts s0r1 first; s0r0 is never consulted.
    got2 = router.search(fleet.q, K)
    _assert_bit_identical(fleet.base, got2)
    assert np.all(np.asarray(got2.coverage) == 1.0)


def test_backoff_schedule():
    p = CallPolicy(backoff_base_s=0.01, backoff_mult=2.0, backoff_max_s=0.05,
                   jitter_frac=0.0)
    assert p.backoff_s(1, 0.7) == 0.0  # first attempt: no backoff
    assert p.backoff_s(2, 0.0) == pytest.approx(0.01)
    assert p.backoff_s(3, 0.0) == pytest.approx(0.02)
    assert p.backoff_s(4, 0.0) == pytest.approx(0.04)
    assert p.backoff_s(9, 0.0) == pytest.approx(0.05)  # capped
    jit = CallPolicy(backoff_base_s=0.01, jitter_frac=0.5)
    assert jit.backoff_s(2, 1.0) == pytest.approx(0.015)


# -- health state machine ----------------------------------------------------


def test_health_state_machine_walk():
    cfg = HealthConfig(degrade_after=1, eject_after=3, probation_after=2,
                       recover_after=2)
    t = HealthTracker(cfg)
    assert t.state("w") is HealthState.HEALTHY and t.admissible("w")
    t.record_failure("w")
    assert t.state("w") is HealthState.DEGRADED and t.admissible("w")
    t.record_success("w")
    assert t.state("w") is HealthState.DEGRADED  # 1 < recover_after
    t.record_success("w")
    assert t.state("w") is HealthState.HEALTHY
    for _ in range(3):
        t.record_failure("w")
    assert t.state("w") is HealthState.EJECTED and not t.admissible("w")
    t.tick()
    assert not t.admissible("w")  # cooldown not served yet
    t.tick()
    assert t.admissible("w")  # probation trial admitted
    assert t.state("w") is HealthState.PROBATION
    t.record_failure("w")  # trial failed: straight back out
    assert t.state("w") is HealthState.EJECTED
    t.tick(), t.tick()
    assert t.admissible("w")
    t.record_success("w")  # trial passed
    assert t.state("w") is HealthState.HEALTHY


def test_ejected_worker_rejoins_through_probation(fleet):
    """A worker that fails transiently past the ejection bar is ejected,
    sits out the cooldown (receiving ZERO traffic), then rejoins through
    a single probation trial — end to end through real router batches.
    R=1 so the router must keep consulting the sole worker."""
    cfg = HealthConfig(degrade_after=1, eject_after=2, probation_after=2,
                       recover_after=1)
    vc = VirtualClock()
    r = load_fleet(fleet.root, replicas=1, degraded="partial",
                   health_cfg=cfg, call_policy=CallPolicy(max_attempts=1),
                   clock=vc.now, sleep=vc.sleep)
    fault = {"s0r0": FaultPolicy.fail_next(2)}
    workers = [FaultyWorker(w, fault[w.key], clock=vc) if w.key in fault
               else w for w in r.workers]
    router = ShardRouter(workers, degraded="partial", health_cfg=cfg,
                         call_policy=CallPolicy(max_attempts=1),
                         clock=vc.now, sleep=vc.sleep)
    faulty = next(w for w in router.workers if w.key == "s0r0")
    # Batch 1 (tick 1): fail #1 -> DEGRADED; shard 0 lost for the batch.
    assert np.asarray(router.search(fleet.q, K).coverage).min() < 1.0
    assert router.health.state("s0r0") is HealthState.DEGRADED
    # Batch 2 (tick 2): degraded but admitted -> fail #2 -> EJECTED.
    router.search(fleet.q, K)
    assert router.health.state("s0r0") is HealthState.EJECTED
    calls_at_ejection = faulty.calls
    # Batch 3 (tick 3): cooldown not served (3 - 2 < probation_after=2):
    # the ejected worker receives no traffic at all.
    router.search(fleet.q, K)
    assert faulty.calls == calls_at_ejection
    assert router.health.state("s0r0") is HealthState.EJECTED
    # Batch 4 (tick 4): probation trial admitted; the fault budget is
    # spent, the trial succeeds -> HEALTHY, full coverage, healthy bits.
    got = router.search(fleet.q, K)
    assert router.health.state("s0r0") is HealthState.HEALTHY
    _assert_bit_identical(fleet.base, got)
    assert np.all(np.asarray(got.coverage) == 1.0)


# -- chaos: seeded schedules are reproducible bit-for-bit --------------------


def test_seeded_chaos_is_reproducible(fleet):
    def run_once():
        vc = VirtualClock()
        meter = ServingMeter()
        router, _ = _router(fleet.root, replicas=2, degraded="partial",
                            policy=CallPolicy(deadline_s=0.04), vc=vc,
                            meter=meter)
        router = inject_faults(router, rate=0.3, seed=7, clock=vc)
        out = []
        for _ in range(4):
            r = router.search(fleet.q, K)
            out.append((np.asarray(r.ids).copy(),
                        np.asarray(r.coverage).copy(), r.shard_status))
        return out, router.health.summary(), vc.now(), \
            meter.shard_summary()["failures"]

    a, ah, at, af = run_once()
    b, bh, bt, bf = run_once()
    for (ai, ac, as_), (bi, bc, bs_) in zip(a, b):
        np.testing.assert_array_equal(ai, bi)
        np.testing.assert_array_equal(ac, bc)
        assert as_ == bs_
    assert ah == bh and at == bt and af == bf


def test_fault_policy_schedules():
    p = FaultPolicy.fail_next(2)
    assert [f.kind if f else None for f in map(p.next_fault, range(4))] == \
        ["fail", "fail", None, None]
    p = FaultPolicy.die_at(2)
    assert [f.kind if f else None for f in map(p.next_fault, range(4))] == \
        [None, None, "die", "die"]
    p = FaultPolicy.latency(0.5, every=2, start=1)
    kinds = [f.kind if f else None for f in map(p.next_fault, range(5))]
    assert kinds == [None, "latency", None, "latency", None]
    p = FaultPolicy.kill_at(2)
    assert [f.kind if f else None for f in map(p.next_fault, range(4))] == \
        [None, None, "kill", None]  # one SIGKILL, not a standing sentence
    # "kill" is drawable from the Bernoulli taxonomy for proc-backend chaos.
    k = FaultPolicy.bernoulli(1.0, seed=1, kinds=("kill",))
    assert all(k.next_fault(i).kind == "kill" for i in range(8))
    # Bernoulli streams are pure functions of (seed, call order).
    pa = FaultPolicy.bernoulli(0.5, seed=3)
    pb = FaultPolicy.bernoulli(0.5, seed=3)
    a = [pa.next_fault(i) for i in range(32)]
    b = [pb.next_fault(i) for i in range(32)]
    assert a == b
    assert any(f is not None for f in a) and any(f is None for f in a)
    assert [f for f in map(FaultPolicy.none().next_fault, range(8))
            if f is not None] == []


def test_kill_fault_requires_a_process_to_kill(fleet):
    """The "kill" kind is REAL process death (DESIGN.md §15): on an
    in-process worker there is nothing to SIGKILL, and the policy says so
    loudly instead of silently downgrading to a simulated raise."""
    router = load_fleet(fleet.root, replicas=1)
    w = FaultyWorker(router.workers[0], FaultPolicy.kill_at(0))
    with pytest.raises(FaultInjectionError, match="no process to kill"):
        w.topk(fleet.q, K)
    # The proc-backend kill path itself is pinned by tests/test_transport.py
    # (SIGKILL mid-batch at R=2 -> bit-identity + respawn).


# -- satellite: torn save_shards reports ALL inconsistent shards -------------


def test_torn_fleet_reports_all_inconsistent_shards(fleet, tmp_path):
    """A torn save (crash between shard writes leaving images from two
    parents) raises ONE SnapshotError naming EVERY inconsistent shard."""
    other = RetrievalIndex.build(np.arange(N),
                                 clustered_vectors(N, D, seed=29), **CFG)
    old_root = str(tmp_path / "old")
    save_shards(other, old_root, NSHARDS)
    root = str(tmp_path / "torn")
    shutil.copytree(fleet.root, root)
    # Crash narrative: shard-000 was rewritten from the new parent, the
    # rest still hold the old fleet -> relative to shard-000, shards 1..3
    # are ALL inconsistent and every one must be named.
    for i in (1, 2, 3):
        shutil.rmtree(os.path.join(root, f"shard-{i:03d}"))
        shutil.copytree(os.path.join(old_root, f"shard-{i:03d}"),
                        os.path.join(root, f"shard-{i:03d}"))
    with pytest.raises(SnapshotError, match="parent snapshot signature") \
            as ei:
        load_router(shard_dirs(root))
    msg = str(ei.value)
    assert msg.count("parent snapshot signature") == 3
    for i in (1, 2, 3):
        assert f"shard {i} " in msg
    assert "3 fleet assembly violation(s)" in msg


def test_assembly_collects_mixed_violation_kinds(fleet, tmp_path):
    """Different violation kinds (overlap + mixed parent) surface together
    in one error, not first-wins."""
    root = str(tmp_path / "multi")
    shutil.copytree(fleet.root, root)
    dirs = shard_dirs(root)

    def tamper(sd, fn):
        path = os.path.join(sd, "manifest.json")
        with open(path) as f:
            m = json.load(f)
        fn(m)
        with open(path, "w") as f:
            json.dump(m, f)

    tamper(dirs[1], lambda m: m["shard"].update(cell_lo=1, cell_hi=3))
    tamper(dirs[3], lambda m: m["parent"].update(fingerprint="deadbeef"))
    with pytest.raises(SnapshotError) as ei:
        load_router(dirs, strict=False)
    msg = str(ei.value)
    assert "overlap" in msg and "parent snapshot signature" in msg


# -- satellite: aggregate_topk under dropped-shard degradation ---------------


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(n_shards=st.integers(2, 6), m=st.integers(1, 3),
                  k=st.sampled_from([1, 3, 7, 10]),
                  seed=st.integers(0, 100_000), wire=st.booleans(),
                  drop_seed=st.integers(0, 100_000))
def test_aggregate_degraded_subset_is_flat_sort_of_survivors(
        n_shards, m, k, seed, wire, drop_seed):
    """Dropping ANY subset of shard runs (replaced by the +inf sentinel the
    degraded path emits) yields exactly the flat-sort top-k of the
    surviving runs — under duplicate-distance ties, bf16 wire storage and
    non-pow2 shard counts.  This is why partial results are well-defined:
    a dead shard's run is the merge identity."""
    Kp = T.next_pow2(k)
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 5, size=(n_shards, m, Kp)).astype(np.float32)
    ids = (np.arange(n_shards)[:, None, None] * 1000
           + np.arange(m)[None, :, None] * 100
           + np.arange(Kp)[None, None, :]).astype(np.int32)
    dead = rng.random((n_shards, m, Kp)) < 0.3
    vals[dead] = np.inf
    ids[dead] = -1
    order = np.argsort(vals, axis=-1, kind="stable")
    vals = np.take_along_axis(vals, order, axis=-1)
    ids = np.take_along_axis(ids, order, axis=-1)
    drop = np.random.default_rng(drop_seed).random(n_shards) < 0.5
    mv, mi = vals.copy(), ids.copy()
    mv[drop] = np.inf
    mi[drop] = -1
    got = aggregate_topk(jnp.asarray(mv), jnp.asarray(mi), k,
                         wire_dtype="bfloat16" if wire else None)
    gv, gi = np.asarray(got.distances), np.asarray(got.indices)
    surv = vals[~drop]
    for j in range(m):
        flat = (np.sort(surv[:, j, :].ravel(), kind="stable")
                if len(surv) else np.empty(0, np.float32))
        want = np.full(k, np.inf, np.float32)
        want[: min(k, len(flat))] = flat[:k]
        np.testing.assert_array_equal(gv[j], want)
        # Every returned entry is a real surviving entry (or the pad).
        from collections import Counter

        pool = Counter(zip(mv[:, j, :].ravel().tolist(),
                           mi[:, j, :].ravel().tolist()))
        pool[(float("inf"), -1)] += k  # pad rows of the pow2 padding
        for v, i in zip(gv[j].tolist(), gi[j].tolist()):
            assert pool[(v, i)] > 0, (v, i)
            pool[(v, i)] -= 1


# -- replicated fleet persistence --------------------------------------------


def test_fleet_manifest_roundtrip(fleet):
    m = read_fleet_manifest(fleet.root)
    assert m["n_shards"] == NSHARDS and m["replicas"] == 2
    router = load_fleet(fleet.root)
    assert router.n_replicas == 2 and len(router.workers) == 2 * NSHARDS
    keys = {w.key for w in router.workers}
    assert keys == {f"s{s}r{r}" for s in range(NSHARDS) for r in range(2)}
    # Replicas are independent restores of the same image: same bits,
    # different arrays.
    g0 = [router.workers[i] for i in router.groups[0]]
    np.testing.assert_array_equal(np.asarray(g0[0].packed),
                                  np.asarray(g0[1].packed))
    assert g0[0].packed is not g0[1].packed
    # Storage is counted once per range, not once per replica.
    assert router.n_live == len(fleet.idx)
    # The recorded factor can be overridden at restore time.
    assert load_fleet(fleet.root, replicas=1).n_replicas == 1
    assert load_fleet(fleet.root, replicas=3).n_replicas == 3


def test_fleet_manifest_torn_root_raises(fleet, tmp_path):
    root = str(tmp_path / "torn")
    shutil.copytree(fleet.root, root)
    shutil.rmtree(os.path.join(root, f"shard-{NSHARDS - 1:03d}"))
    with pytest.raises(SnapshotError, match="torn fleet"):
        read_fleet_manifest(root)


def test_fleet_root_without_manifest_loads_unreplicated(fleet, tmp_path):
    """Pre-replication roots (no fleet.json) stay loadable at R=1."""
    root = str(tmp_path / "legacy")
    shutil.copytree(fleet.root, root)
    os.remove(os.path.join(root, "fleet.json"))
    m = read_fleet_manifest(root)
    assert m["replicas"] == 1
    router = load_fleet(root)
    assert router.n_replicas == 1
    _assert_bit_identical(fleet.base, router.search(fleet.q, K))


# -- engine + service integration --------------------------------------------


def test_coverage_propagates_through_engine_chunking(fleet):
    """The engine chunks big batches; per-query coverage must concatenate
    and per-shard status must fold worst-wins across chunks."""
    from repro.serving import EngineConfig, QueryEngine

    router, _ = _router(fleet.root, replicas=1, degraded="partial",
                        kill={"s1r0"})
    eng = QueryEngine(router, EngineConfig(k=K, min_batch=8, max_batch=8))
    got = eng.search(fleet.q, K)  # 24 queries -> 3 chunks of 8
    cov = np.asarray(got.coverage)
    assert cov.shape == (len(fleet.q),)
    assert cov.min() < 1.0
    assert dict(got.shard_status)[1] == "failed"
    direct = router.search(fleet.q, K)
    np.testing.assert_array_equal(cov, np.asarray(direct.coverage))
    np.testing.assert_array_equal(np.asarray(got.ids),
                                  np.asarray(direct.ids))


def test_service_restores_replicated_fleet(tmp_path):
    import jax

    from repro.configs import registry as REG
    from repro.models.nn import split_params
    from repro.serving import ServiceConfig, TwoTowerRetrievalService

    arch = REG.get("two-tower-retrieval")
    cfg = arch.smoke_config()
    values, _ = split_params(arch.init_params(jax.random.PRNGKey(0), cfg))
    root = str(tmp_path / "shards")
    svc = TwoTowerRetrievalService(
        values, cfg, ServiceConfig(k=5, ivf_cells=8, nprobe=8, shards=2,
                                   replicas=2, degraded="partial",
                                   snapshot_dir=root))
    rng = np.random.default_rng(1)
    n = 512
    fields = rng.integers(0, min(cfg.i_sizes()),
                          size=(n, cfg.n_item_fields)).astype(np.int32)
    svc.build_corpus(np.arange(n), fields)
    svc.save_shards()
    assert read_fleet_manifest(root)["replicas"] == 2
    svc.restore_shards()
    assert svc.router.n_replicas == 2
    assert svc.router.degraded == "partial"
    ukeys = np.arange(7)
    ufields = rng.integers(0, min(cfg.u_sizes()),
                           size=(7, cfg.n_user_fields)).astype(np.int32)
    ids, scores = svc.recommend(ukeys, ufields)
    assert ids.shape == (7, 5) and np.all(ids >= 0)
    st_ = svc.stats()
    assert st_["fleet"]["replicas"] == 2
    assert st_["fleet"]["dispatch"]["calls"] > 0
    assert all(h["state"] == "healthy"
               for h in st_["fleet"]["health"].values())


# -- the analytic availability model -----------------------------------------


def test_replicated_fleet_model_sanity():
    m1 = replicated_fleet_model(4, 1, shards_dispatched=3.0, fault_rate=0.1)
    m2 = replicated_fleet_model(4, 2, shards_dispatched=3.0, fault_rate=0.1)
    m3 = replicated_fleet_model(4, 3, shards_dispatched=3.0, fault_rate=0.1)
    # Availability is monotone in R; storage pays linearly for it.
    assert m1["p_query_complete"] < m2["p_query_complete"] \
        < m3["p_query_complete"]
    assert m1["expected_coverage"] == pytest.approx(0.9)
    assert m2["expected_coverage"] == pytest.approx(0.99)
    assert m2["storage_factor"] == 2.0
    healthy = replicated_fleet_model(4, 2, shards_dispatched=3.0)
    assert healthy["p_query_complete"] == 1.0
    assert healthy["dispatch_factor"] == 1.0
