"""repro.serving: index lifecycle exactness, segment merge, batch padding.

The contract under test (DESIGN.md §Serving): a RetrievalIndex is EXACT after
any interleaving of insert/upsert/delete/compact — equal to brute-force
re-running ``core.knn`` on the live rows — and the engine's pow2 batch
padding never changes any row's results.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knn import knn_query
from repro.serving import (
    EmbeddingCache,
    EngineConfig,
    QueryEngine,
    RetrievalIndex,
)

SETTINGS = dict(max_examples=15, deadline=None)


def _brute(live_ids, live_vecs, q, k, distance="sqeuclidean"):
    """Reference: rebuild from scratch and solve with core.knn."""
    r = knn_query(jnp.asarray(q), jnp.asarray(live_vecs), k, distance=distance)
    ids = np.asarray(live_ids)[np.asarray(r.indices)]
    ids = np.where(np.asarray(r.indices) >= 0, ids, -1)
    return ids, np.asarray(r.distances)


def _assert_matches_brute(res, live_ids, live_vecs, q, k, distance="sqeuclidean"):
    bi, bv = _brute(live_ids, live_vecs, q, k, distance)
    np.testing.assert_array_equal(np.asarray(res.ids), bi)
    np.testing.assert_allclose(np.asarray(res.distances), bv, rtol=1e-5, atol=1e-6)


class _Mirror:
    """Host-side mirror of the live set (insertion-ordered like the index)."""

    def __init__(self):
        self.rows: dict[int, np.ndarray] = {}

    def upsert(self, ids, vecs):
        for i, v in zip(ids, vecs):
            self.rows.pop(int(i), None)
            self.rows[int(i)] = v

    def delete(self, ids):
        for i in ids:
            self.rows.pop(int(i), None)

    def live(self):
        ids = np.fromiter(self.rows.keys(), np.int64, len(self.rows))
        return ids, np.stack(list(self.rows.values()))


def test_build_search_matches_brute():
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((300, 24)).astype(np.float32)
    ids = np.arange(100, 400)
    idx = RetrievalIndex.build(ids, vecs)
    q = rng.standard_normal((9, 24)).astype(np.float32)
    _assert_matches_brute(idx.search(q, 11), ids, vecs, q, 11)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 10_000), k=st.integers(1, 17),
                  impl=st.sampled_from(["jnp", "fused"]))
def test_interleaved_lifecycle_matches_brute_rebuild(seed, k, impl):
    """insert/upsert/delete/compact in random interleavings == brute rebuild."""
    rng = np.random.default_rng(seed)
    d = 8
    n0 = int(rng.integers(20, 120))
    vecs = rng.standard_normal((n0, d)).astype(np.float32)
    ids = rng.permutation(10_000)[:n0]
    idx = RetrievalIndex.build(ids, vecs, impl=impl)
    mirror = _Mirror()
    mirror.upsert(ids, vecs)
    next_id = 20_000

    q = rng.standard_normal((5, d)).astype(np.float32)
    for _ in range(4):
        op = rng.integers(0, 4)
        if op == 0:  # insert fresh ids
            n = int(rng.integers(1, 40))
            new_ids = np.arange(next_id, next_id + n)
            next_id += n
            new_vecs = rng.standard_normal((n, d)).astype(np.float32)
            idx.insert(new_ids, new_vecs)
            mirror.upsert(new_ids, new_vecs)
        elif op == 1:  # upsert over random existing + some fresh
            live_ids, _ = mirror.live()
            n = int(rng.integers(1, 1 + min(20, len(live_ids))))
            up = rng.choice(live_ids, size=n, replace=False)
            up_vecs = rng.standard_normal((n, d)).astype(np.float32)
            idx.upsert(up, up_vecs)
            mirror.upsert(up, up_vecs)
        elif op == 2:  # delete some (plus a non-existent id: must be a no-op)
            live_ids, _ = mirror.live()
            avail = min(20, len(live_ids) - k)  # keep >= k rows live
            n = int(rng.integers(1, 1 + avail)) if avail >= 1 else 0
            dead = rng.choice(live_ids, size=n, replace=False)
            idx.delete(np.concatenate([dead, [99_999_999]]))
            mirror.delete(dead)
        else:
            idx.compact()
            assert idx.n_dead == 0
        live_ids, live_vecs = mirror.live()
        assert len(idx) == len(live_ids)
        _assert_matches_brute(idx.search(q, k), live_ids, live_vecs, q, k)


def test_delta_plus_main_merge_equals_single_segment():
    """Same rows split main/delta vs packed in one segment: identical search."""
    rng = np.random.default_rng(3)
    d, k = 16, 9
    a = rng.standard_normal((150, d)).astype(np.float32)
    b = rng.standard_normal((70, d)).astype(np.float32)
    ids_a = np.arange(150)
    ids_b = np.arange(1000, 1070)
    q = rng.standard_normal((6, d)).astype(np.float32)

    split = RetrievalIndex.build(ids_a, a)  # main
    split.insert(ids_b, b)  # delta
    assert split._delta_n == 70  # really exercising the two-segment path

    packed = RetrievalIndex.build(
        np.concatenate([ids_a, ids_b]), np.concatenate([a, b]))
    rs, rp = split.search(q, k), packed.search(q, k)
    np.testing.assert_array_equal(np.asarray(rs.ids), np.asarray(rp.ids))
    np.testing.assert_allclose(np.asarray(rs.distances),
                               np.asarray(rp.distances), rtol=1e-5, atol=1e-6)


def test_batch_padding_invariance():
    """Engine pow2 padding returns bit-identical rows to the unpadded index."""
    rng = np.random.default_rng(4)
    d, k = 12, 5
    idx = RetrievalIndex.build(
        np.arange(256), rng.standard_normal((256, d)).astype(np.float32))
    eng = QueryEngine(idx, EngineConfig(k=k, min_batch=8, max_batch=32))
    for m in (1, 3, 8, 13, 33, 70):  # below/at/above bucket + chunking
        q = rng.standard_normal((m, d)).astype(np.float32)
        r_eng = eng.search(q)
        r_idx = idx.search(jnp.asarray(q), k)
        np.testing.assert_array_equal(np.asarray(r_eng.ids),
                                      np.asarray(r_idx.ids))
        np.testing.assert_array_equal(np.asarray(r_eng.distances),
                                      np.asarray(r_idx.distances))
    s = eng.meter.summary()
    assert s["batches"] + s["compile_batches"] == 1 + 1 + 1 + 1 + 2 + 3


def test_fewer_live_rows_than_k_pads_with_minus_one():
    rng = np.random.default_rng(5)
    idx = RetrievalIndex.build(
        np.arange(6), rng.standard_normal((6, 4)).astype(np.float32))
    idx.delete([0, 1])
    res = idx.search(rng.standard_normal((2, 4)).astype(np.float32), 6)
    ids = np.asarray(res.ids)
    assert (ids[:, :4] >= 0).all() and (ids[:, 4:] == -1).all()
    assert np.isposinf(np.asarray(res.distances)[:, 4:]).all()


def test_insert_existing_id_raises_and_upsert_replaces():
    rng = np.random.default_rng(6)
    v = rng.standard_normal((4, 4)).astype(np.float32)
    idx = RetrievalIndex.build([1, 2, 3, 4], v)
    with pytest.raises(KeyError):
        idx.insert([2], v[:1])
    new_row = np.zeros((1, 4), np.float32)
    idx.upsert([2], new_row)
    assert len(idx) == 4
    res = idx.search(np.zeros((1, 4), np.float32), 1)
    assert int(np.asarray(res.ids)[0, 0]) == 2  # the replaced row wins at 0


def test_engine_queue_roundtrip():
    rng = np.random.default_rng(7)
    idx = RetrievalIndex.build(
        np.arange(64), rng.standard_normal((64, 8)).astype(np.float32))
    eng = QueryEngine(idx, EngineConfig(k=3))
    q = rng.standard_normal((5, 8)).astype(np.float32)
    for i, row in enumerate(q):
        eng.submit(("req", i), row)
    assert eng.pending == 5
    out = eng.flush()
    assert eng.pending == 0 and len(out) == 5
    ref = idx.search(jnp.asarray(q), 3)
    for i in range(5):
        np.testing.assert_array_equal(out[("req", i)][1], np.asarray(ref.ids)[i])


def test_embedding_cache_lru_and_stats():
    c = EmbeddingCache(capacity=2)
    c.put(1, np.ones(3))
    c.put(2, np.full(3, 2.0))
    assert c.get(1) is not None  # 1 now most-recent
    c.put(3, np.full(3, 3.0))  # evicts 2
    assert c.get(2) is None and c.get(3) is not None
    found, missing = c.get_many([1, 2, 3])
    assert set(found) == {1, 3} and missing == [2]
    assert c.hits == 4 and c.misses == 2


def test_sharded_main_segment_matches_local_8dev():
    """Query-sharded main scoring (mesh) == local path, tombstones included."""
    from conftest import run_with_devices

    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.serving import RetrievalIndex

        rng = np.random.default_rng(0)
        d, k = 16, 9
        vecs = rng.standard_normal((512, d)).astype(np.float32)
        ids = np.arange(512)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sharded = RetrievalIndex.build(ids, vecs, mesh=mesh)
        local = RetrievalIndex.build(ids, vecs)
        fresh = rng.standard_normal((40, d)).astype(np.float32)
        for idx in (sharded, local):
            idx.delete(np.arange(0, 512, 7))
            idx.insert(np.arange(9000, 9040), fresh)
        rng2 = np.random.default_rng(1)
        q = rng2.standard_normal((10, d)).astype(np.float32)
        rs = sharded.search(jnp.asarray(q), k)
        rl = local.search(jnp.asarray(q), k)
        assert np.array_equal(np.asarray(rs.ids), np.asarray(rl.ids))
        np.testing.assert_allclose(np.asarray(rs.distances),
                                   np.asarray(rl.distances), rtol=1e-5)
        print("OK")
    """)
