"""Selection-network primitives (paper Sect. 6 TPU adaptation) vs oracles."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as T

SETTINGS = dict(max_examples=40, deadline=None)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    rows=st.integers(1, 8),
    logl=st.integers(0, 7),
    seed=st.integers(0, 100_000),
    ascending=st.booleans(),
)
def test_bitonic_sort_matches_jnp_sort(rows, logl, seed, ascending):
    L = 2 ** logl
    g = np.random.default_rng(seed)
    vals = jnp.asarray(g.standard_normal((rows, L), dtype=np.float32))
    idx = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (rows, L))
    sv, si = T.bitonic_sort_kv(vals, idx, ascending=ascending)
    ref = jnp.sort(vals, axis=-1)
    if not ascending:
        ref = ref[:, ::-1]
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(ref))
    # index consistency: vals[row, si] == sv
    taken = np.take_along_axis(np.asarray(vals), np.asarray(si), axis=1)
    np.testing.assert_array_equal(taken, np.asarray(sv))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    rows=st.integers(1, 6), logk=st.integers(0, 6), seed=st.integers(0, 100_000)
)
def test_merge_topk_sorted(rows, logk, seed):
    """min(a, reverse(b)) + bitonic merge == K smallest of the union."""
    K = 2 ** logk
    g = np.random.default_rng(seed)
    a = np.sort(g.standard_normal((rows, K), dtype=np.float32), axis=1)
    b = np.sort(g.standard_normal((rows, K), dtype=np.float32), axis=1)
    ai = np.arange(K, dtype=np.int32) * np.ones((rows, 1), np.int32)
    bi = ai + K
    mv, mi = T.merge_topk_sorted(jnp.asarray(a), jnp.asarray(ai),
                                 jnp.asarray(b), jnp.asarray(bi))
    ref = np.sort(np.concatenate([a, b], axis=1), axis=1)[:, :K]
    np.testing.assert_array_equal(np.asarray(mv), ref)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    m=st.integers(1, 10), n=st.integers(1, 200), k=st.integers(1, 32),
    seed=st.integers(0, 100_000),
)
def test_topk_smallest_oracle(m, n, k, seed):
    k = min(k, n)
    g = np.random.default_rng(seed)
    x = jnp.asarray(g.standard_normal((m, n), dtype=np.float32))
    v, i = T.topk_smallest(x, k)
    ref = np.sort(np.asarray(x), axis=1)[:, :k]
    np.testing.assert_array_equal(np.asarray(v), ref)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    m=st.integers(1, 6), k=st.integers(1, 16), tiles=st.integers(1, 5),
    bn=st.integers(1, 64), seed=st.integers(0, 100_000),
    skip=st.booleans(),
)
def test_update_running_streams_tiles(m, k, tiles, bn, seed, skip):
    """Streaming tile folds == one-shot top-k over the concatenation."""
    g = np.random.default_rng(seed)
    data = g.standard_normal((m, tiles * bn), dtype=np.float32)
    run = T.init_running(m, k)
    for t in range(tiles):
        tile = jnp.asarray(data[:, t * bn:(t + 1) * bn])
        run = T.update_running(*run, tile, t * bn, threshold_skip=skip)
    v, i = T.finalize_topk(*run, k)
    kk = min(k, tiles * bn)
    ref = np.sort(data, axis=1)[:, :kk]
    np.testing.assert_allclose(np.asarray(v)[:, :kk], ref, atol=1e-6)
    # indices point at the right values
    got = np.take_along_axis(data, np.asarray(i)[:, :kk], axis=1)
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_merge_many_sorted():
    g = np.random.default_rng(0)
    S, m, K = 5, 4, 8
    parts = np.sort(g.standard_normal((S, m, K), dtype=np.float32), axis=-1)
    idx = np.broadcast_to(np.arange(K, dtype=np.int32), (S, m, K)).copy()
    v, i = T.merge_many_sorted(jnp.asarray(parts), jnp.asarray(idx), K)
    ref = np.sort(parts.transpose(1, 0, 2).reshape(m, -1), axis=1)[:, :K]
    np.testing.assert_array_equal(np.asarray(v), ref)


def test_next_pow2():
    assert [T.next_pow2(i) for i in (1, 2, 3, 5, 8, 100)] == [1, 2, 4, 8, 8, 128]
